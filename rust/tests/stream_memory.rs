//! Asserts the streaming memory bound with the benchkit allocation gauge:
//! the tile pipeline's peak *extra* allocation is `O(tile_rows·c + s²)`
//! beyond the `C` output (prototype: `O(tile_rows·n)` instead of `O(n²)`),
//! and — crucially — independent of `n`.
//!
//! Everything lives in ONE `#[test]`: the gauge counters are process-wide,
//! so concurrent tests in the same binary would pollute each other's
//! measurements (see `benchkit::alloc`). Each measured build runs once as
//! a warmup first so grow-only thread-local GEMM pack buffers and pool
//! threads are excluded from the gauged steady state.

use fastspsd::benchkit::alloc::{self, AllocGauge, CountingAlloc};
use fastspsd::coordinator::oracle::RbfOracle;
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::linalg::Matrix;
use fastspsd::spsd::{self, FastConfig};
use fastspsd::util::Rng;
use std::sync::Arc;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const F: usize = 8; // bytes per f64
const C: usize = 12;
const S: usize = 36;
const TILE: usize = 16;

fn oracle(n: usize, seed: u64) -> RbfOracle {
    let mut rng = Rng::new(seed);
    let x = Arc::new(Matrix::randn(n, 6, &mut rng));
    RbfOracle::cpu(x, 0.5)
}

/// Gauge one closure's peak extra allocation after a warmup run.
fn gauge<R>(mut f: impl FnMut() -> R) -> usize {
    let _warm = f();
    let g = AllocGauge::start();
    let _r = f();
    g.peak_extra_bytes()
}

#[test]
fn streamed_builds_respect_the_memory_bound() {
    assert!(Vec::from([1u8, 2]).len() == 2);
    assert!(alloc::installed(), "counting allocator must be the global allocator here");

    // --- fast model (selection sketch): extra beyond the C output is
    // O(tile_rows·c + s²), with a fixed slack for factorization scratch.
    let n1 = 600;
    let o1 = oracle(n1, 1);
    let p1 = spsd::uniform_p(n1, C, &mut Rng::new(2));
    let tiled = ExecPolicy::streamed(TILE);
    let fast_extra_1 = gauge(|| {
        exec::fast(&o1, &p1, FastConfig::uniform(S), &tiled, &mut Rng::new(3)).result
    });
    let c_bytes_1 = n1 * C * F;
    let bound_1 = c_bytes_1 + 24 * (TILE * C + S * S) * F + 256 * 1024;
    assert!(
        fast_extra_1 <= bound_1,
        "fast streamed peak extra {fast_extra_1} B exceeds O(tile·c + s²) bound {bound_1} B"
    );

    // --- n-independence: tripling n must only grow the peak by ~the C
    // output's growth — the transient working set does not scale with n.
    let n2 = 1800;
    let o2 = oracle(n2, 4);
    let p2 = spsd::uniform_p(n2, C, &mut Rng::new(5));
    let fast_extra_2 = gauge(|| {
        exec::fast(&o2, &p2, FastConfig::uniform(S), &tiled, &mut Rng::new(6)).result
    });
    let c_growth = (n2 - n1) * C * F;
    assert!(
        fast_extra_2 <= fast_extra_1 + c_growth + 128 * 1024,
        "peak extra grew superlinearly with n: {fast_extra_1} B @ n={n1} vs {fast_extra_2} B @ n={n2} \
         (C growth only accounts for {c_growth} B)"
    );

    // --- fast model (leverage family): the streamed Gram estimator keeps
    // the score state at O(c²), so the peak extra obeys the SAME
    // O(tile·c + s²) envelope as uniform — the acceptance criterion. The
    // historical resident-SVD scoring would add an O(n·c) workspace here
    // and blow the n-independence check below.
    let lev_extra_1 = gauge(|| {
        exec::fast(&o1, &p1, FastConfig::leverage(S), &tiled, &mut Rng::new(7)).result
    });
    assert!(
        lev_extra_1 <= bound_1,
        "leverage streamed peak extra {lev_extra_1} B exceeds O(tile·c + s²) bound {bound_1} B"
    );

    // n-independence for leverage: tripling n must only grow the peak by
    // ~the C output's growth, exactly like the uniform family.
    let lev_extra_2 = gauge(|| {
        exec::fast(&o2, &p2, FastConfig::leverage(S), &tiled, &mut Rng::new(8)).result
    });
    assert!(
        lev_extra_2 <= lev_extra_1 + c_growth + 128 * 1024,
        "leverage peak extra grew superlinearly with n: {lev_extra_1} B @ n={n1} vs \
         {lev_extra_2} B @ n={n2} (C growth only accounts for {c_growth} B)"
    );

    // --- prototype: streamed tiles replace the n x n materialization.
    let proto_streamed = gauge(|| exec::prototype(&o1, &p1, &tiled).result);
    let proto_materialized = gauge(|| exec::prototype(&o1, &p1, &ExecPolicy::Materialized).result);
    let k_bytes = n1 * n1 * F;
    assert!(
        proto_materialized >= k_bytes,
        "materialized prototype must allocate the full kernel ({k_bytes} B), saw {proto_materialized} B"
    );
    assert!(
        proto_streamed < k_bytes / 2,
        "streamed prototype peak {proto_streamed} B should be well below the n² kernel {k_bytes} B"
    );

    // --- and the streamed result is still the same model (sanity, so the
    // gauge can't pass on a build that silently did nothing).
    let a = exec::prototype(&o1, &p1, &tiled).result;
    let b = exec::prototype(&o1, &p1, &ExecPolicy::Materialized).result;
    let rel = a.u.sub(&b.u).fro_norm() / b.u.fro_norm().max(1e-300);
    assert!(rel <= 1e-12, "streamed prototype diverged: {rel}");
}
