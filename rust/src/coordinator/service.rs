//! The approximation service: the Layer-3 request loop.
//!
//! Clients submit [`ApproxRequest`]s (which model, c, s, downstream task
//! size k); the service routes them to a worker pool with a bounded queue
//! (backpressure), each worker builds the approximation against the shared
//! kernel oracle — kernel blocks flow through the PJRT engine — and replies
//! with eigenvalues + timings. Latency and queue-wait histograms feed the
//! serving-style end-to-end example.

use super::metrics::Metrics;
use super::oracle::{KernelOracle, RbfOracle};
use super::planner;
use crate::pool::ThreadPool;
use crate::sketch::SketchKind;
use crate::spsd::{self, FastConfig, LeverageBasis};
use crate::stream::{ResidencyConfig, ResidencyStats, StreamConfig};
use crate::util::Rng;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Which model a request wants.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MethodSpec {
    Nystrom,
    Prototype,
    Fast { s: usize, kind: SketchKind },
}

impl MethodSpec {
    pub fn name(&self) -> String {
        match self {
            MethodSpec::Nystrom => "nystrom".into(),
            MethodSpec::Prototype => "prototype".into(),
            MethodSpec::Fast { s, kind } => format!("fast[{},s={s}]", kind.name()),
        }
    }
}

/// One approximation job.
#[derive(Debug, Clone)]
pub struct ApproxRequest {
    pub id: u64,
    pub method: MethodSpec,
    /// sketch size c (columns of C).
    pub c: usize,
    /// downstream top-k eigenpairs to return.
    pub k: usize,
    pub seed: u64,
    /// `Some(t)`: build through the tile pipeline in `t`-row tiles (the
    /// planner emits this when the memory budget demands it); `None`: the
    /// materialized path.
    pub tile_rows: Option<usize>,
    /// `Some(bytes)`: route the build through the tile residency layer —
    /// [`planner::plan_residency`] splits the bytes into a pipeline tile
    /// height (unless `tile_rows` pins one) and a hot-tile LRU budget,
    /// cold tiles spill to the service's spill directory, and the response
    /// carries the hit/miss/spill counters. Supported for Nyström and the
    /// column-selection fast models; other methods run the plain path.
    pub residency_budget: Option<u64>,
}

/// Reply for one job.
#[derive(Debug, Clone)]
pub struct ApproxResponse {
    pub id: u64,
    pub method: String,
    /// top-k eigenvalues of C U C^T.
    pub eigvals: Vec<f64>,
    /// kernel entries observed building this approximation.
    pub entries: u64,
    /// seconds spent computing (excl. queue wait).
    pub compute_secs: f64,
    /// seconds from submit to completion.
    pub total_secs: f64,
    /// Residency counters (hits, misses, spilled bytes) when the request
    /// routed through the tile residency layer.
    pub residency: Option<ResidencyStats>,
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub workers: usize,
    /// max queued jobs before `submit` blocks (backpressure).
    pub queue_capacity: usize,
    /// Directory for residency spill arenas (`None` = the system temp
    /// dir). Arena files are per-request and removed when the build ends.
    pub spill_dir: Option<PathBuf>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { workers: 4, queue_capacity: 64, spill_dir: None }
    }
}

/// The running service.
pub struct ApproxService {
    oracle: Arc<RbfOracle>,
    pool: ThreadPool,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicU64>,
    spill_dir: Option<PathBuf>,
}

impl ApproxService {
    pub fn new(oracle: Arc<RbfOracle>, cfg: ServiceConfig) -> Self {
        ApproxService {
            oracle,
            pool: ThreadPool::new(cfg.workers.max(1), cfg.queue_capacity.max(1)),
            metrics: Arc::new(Metrics::default()),
            inflight: Arc::new(AtomicU64::new(0)),
            spill_dir: cfg.spill_dir,
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Submit a job; the response is delivered on `reply`. Blocks when the
    /// queue is full.
    pub fn submit(&self, req: ApproxRequest, reply: mpsc::Sender<ApproxResponse>) {
        self.metrics.requests.inc();
        self.inflight.fetch_add(1, Ordering::Relaxed);
        let oracle = Arc::clone(&self.oracle);
        let metrics = Arc::clone(&self.metrics);
        let inflight = Arc::clone(&self.inflight);
        let spill_dir = self.spill_dir.clone();
        let submitted = Instant::now();
        self.pool.submit(move || {
            let started = Instant::now();
            metrics.queue_wait.observe(started.duration_since(submitted));
            let resp = run_request(oracle.as_ref(), &req, spill_dir.as_deref(), submitted);
            metrics.latency.observe(submitted.elapsed());
            match &resp {
                Ok(_) => metrics.completed.inc(),
                Err(_) => metrics.failed.inc(),
            }
            inflight.fetch_sub(1, Ordering::Relaxed);
            if let Ok(r) = resp {
                let _ = reply.send(r);
            }
        });
    }

    /// Wait for every submitted job to finish.
    pub fn drain(&self) {
        self.pool.wait_idle();
    }
}

fn run_request(
    oracle: &RbfOracle,
    req: &ApproxRequest,
    spill_dir: Option<&Path>,
    submitted: Instant,
) -> anyhow::Result<ApproxResponse> {
    let mut rng = Rng::new(req.seed);
    let n = oracle.n();
    let c = req.c.clamp(1, n);
    let p = spsd::uniform_p(n, c, &mut rng);
    let t0 = Instant::now();
    // Residency routing: the planner splits the byte budget into a tile
    // height + LRU budget; the request's explicit tile_rows (if any) wins.
    let routed = req.residency_budget.and_then(|budget| {
        let split = planner::plan_residency(n, c, budget);
        let tile = req.tile_rows.unwrap_or(split.tile_rows);
        let stream_cfg = StreamConfig::tiled(tile);
        // Spill only when the planner says the cache can't hold the panel;
        // otherwise a RAM-only layer avoids writing an arena nobody reads.
        let mut rc = if split.spill {
            ResidencyConfig::new(split.cache_budget)
        } else {
            ResidencyConfig::ram_only(split.cache_budget)
        }
        .with_tile_rows(tile);
        if split.spill {
            if let Some(dir) = spill_dir {
                rc = rc.with_spill_dir(dir);
            }
        }
        match req.method {
            MethodSpec::Nystrom => Some(spsd::nystrom_resident(oracle, &p, stream_cfg, &rc)),
            MethodSpec::Fast { s, kind } if kind.is_column_selection() => {
                Some(spsd::fast_streamed_resident(
                    oracle,
                    &p,
                    FastConfig { s, kind, force_p_in_s: true, leverage_basis: LeverageBasis::Gram },
                    stream_cfg,
                    &rc,
                    &mut rng,
                ))
            }
            // prototype / projection sketches stream the full K: no
            // reloadable working set — run the plain path below
            _ => None,
        }
    });
    let (approx, residency) = match routed {
        Some((approx, stats)) => (approx, Some(stats)),
        None => {
            let stream_cfg = match req.tile_rows {
                Some(t) => StreamConfig::tiled(t),
                None => StreamConfig::whole(),
            };
            let approx = match req.method {
                MethodSpec::Nystrom => spsd::nystrom_streamed(oracle, &p, stream_cfg),
                MethodSpec::Prototype => spsd::prototype_streamed(oracle, &p, stream_cfg),
                MethodSpec::Fast { s, kind } => spsd::fast_streamed(
                    oracle,
                    &p,
                    // Gram basis: leverage requests stream with O(c²) score
                    // state, matching the peak the planner predicts here.
                    FastConfig { s, kind, force_p_in_s: true, leverage_basis: LeverageBasis::Gram },
                    stream_cfg,
                    &mut rng,
                ),
            };
            (approx, None)
        }
    };
    let (eigvals, _vecs) = approx.eig_k(req.k.max(1));
    Ok(ApproxResponse {
        id: req.id,
        method: req.method.name(),
        eigvals,
        entries: approx.entries_observed,
        compute_secs: t0.elapsed().as_secs_f64(),
        total_secs: submitted.elapsed().as_secs_f64(),
        residency,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn service(n: usize, workers: usize, cap: usize) -> ApproxService {
        let mut rng = Rng::new(0);
        let x = Arc::new(Matrix::randn(n, 6, &mut rng));
        let oracle = Arc::new(RbfOracle::cpu(x, 0.4));
        ApproxService::new(oracle, ServiceConfig { workers, queue_capacity: cap, spill_dir: None })
    }

    #[test]
    fn serves_all_methods() {
        let svc = service(80, 2, 16);
        let (tx, rx) = mpsc::channel();
        let methods = [
            MethodSpec::Nystrom,
            MethodSpec::Prototype,
            MethodSpec::Fast { s: 24, kind: SketchKind::Uniform },
        ];
        for (i, m) in methods.iter().enumerate() {
            svc.submit(
                ApproxRequest {
                    id: i as u64,
                    method: *m,
                    c: 8,
                    k: 3,
                    seed: i as u64,
                    tile_rows: None,
                    residency_budget: None,
                },
                tx.clone(),
            );
        }
        svc.drain();
        drop(tx);
        let mut resps: Vec<ApproxResponse> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 3);
        for r in &resps {
            assert_eq!(r.eigvals.len(), 3);
            assert!(r.eigvals[0] >= r.eigvals[1]);
            assert!(r.compute_secs <= r.total_secs + 1e-9);
        }
        // prototype sees the most entries, nystrom the fewest
        assert!(resps[1].entries > resps[2].entries);
        assert!(resps[2].entries > resps[0].entries);
        assert_eq!(svc.metrics().completed.get(), 3);
        assert_eq!(svc.metrics().failed.get(), 0);
        assert_eq!(svc.metrics().latency.count(), 3);
    }

    #[test]
    fn many_concurrent_requests_complete() {
        let svc = service(60, 4, 8);
        let (tx, rx) = mpsc::channel();
        let total = 30u64;
        for i in 0..total {
            svc.submit(
                ApproxRequest {
                    id: i,
                    method: MethodSpec::Fast { s: 16, kind: SketchKind::Uniform },
                    c: 6,
                    k: 2,
                    seed: i,
                    tile_rows: None,
                    residency_budget: None,
                },
                tx.clone(),
            );
        }
        svc.drain();
        drop(tx);
        assert_eq!(rx.iter().count() as u64, total);
        assert_eq!(svc.metrics().requests.get(), total);
        assert_eq!(svc.inflight(), 0);
    }

    #[test]
    fn streamed_requests_match_materialized_results() {
        // The same (method, c, seed) served materialized and through the
        // tile pipeline must agree: bit-identically for the gather-based
        // fast/nystrom paths, to reduction-reordering tolerance for the
        // prototype. One worker: the per-request entry delta is read off a
        // single shared oracle counter, so overlapping builds would
        // misattribute entries and make the equality assertion flaky.
        let svc = service(70, 1, 16);
        let (tx, rx) = mpsc::channel();
        let methods = [
            MethodSpec::Nystrom,
            MethodSpec::Prototype,
            MethodSpec::Fast { s: 20, kind: SketchKind::Uniform },
            MethodSpec::Fast { s: 20, kind: SketchKind::Leverage { scaled: false } },
        ];
        let mut id = 0u64;
        for m in methods {
            for tile_rows in [None, Some(13)] {
                svc.submit(
                    ApproxRequest { id, method: m, c: 7, k: 4, seed: 42, tile_rows, residency_budget: None },
                    tx.clone(),
                );
                id += 1;
            }
        }
        svc.drain();
        drop(tx);
        let mut resps: Vec<ApproxResponse> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 8);
        for pair in resps.chunks(2) {
            let (mat, st) = (&pair[0], &pair[1]);
            assert_eq!(mat.entries, st.entries, "{}: entry accounting must not change", mat.method);
            for (a, b) in mat.eigvals.iter().zip(&st.eigvals) {
                let scale = mat.eigvals[0].abs().max(1e-12);
                assert!(
                    (a - b).abs() <= 1e-9 * scale,
                    "{}: streamed eig {b} vs materialized {a}",
                    mat.method
                );
            }
        }
    }

    #[test]
    fn residency_requests_match_plain_and_report_stats() {
        // The same (method, c, seed) with and without residency routing
        // must agree bit-identically (the routed build replays the same
        // rng sequence and gathers the same tiles), carry the same entry
        // count, and attach hit/miss/spill counters. One worker for the
        // same shared-counter reason as above.
        let svc = service(70, 1, 16);
        let (tx, rx) = mpsc::channel();
        let methods = [
            MethodSpec::Nystrom,
            MethodSpec::Fast { s: 20, kind: SketchKind::Uniform },
            MethodSpec::Fast { s: 20, kind: SketchKind::Leverage { scaled: false } },
        ];
        let mut id = 0u64;
        for m in methods {
            for residency_budget in [None, Some(0u64)] {
                svc.submit(
                    ApproxRequest {
                        id,
                        method: m,
                        c: 7,
                        k: 4,
                        seed: 42,
                        tile_rows: Some(13),
                        residency_budget,
                    },
                    tx.clone(),
                );
                id += 1;
            }
        }
        svc.drain();
        drop(tx);
        let mut resps: Vec<ApproxResponse> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 6);
        for pair in resps.chunks(2) {
            let (plain, routed) = (&pair[0], &pair[1]);
            assert!(plain.residency.is_none());
            let stats = routed.residency.expect("routed request must report stats");
            assert_eq!(plain.entries, routed.entries, "{}", plain.method);
            for (a, b) in plain.eigvals.iter().zip(&routed.eigvals) {
                assert_eq!(a, b, "{}: residency must not change results", plain.method);
            }
            assert_eq!(stats.computes, 70u64.div_ceil(13), "one oracle pass per tile");
            if routed.method.contains("leverage") {
                // two-pass plan at a zero RAM budget: pass 2 reads the arena
                assert_eq!(stats.spill_hits, stats.computes, "{}", routed.method);
            }
        }
    }
}
