//! SPSD matrix approximation models (paper §3.2 and §4):
//!
//! - Nyström — `U = W† = (P^T K P)†` (eq. 3),
//! - prototype — `U* = C† K (C†)^T` (eq. 2, requires all of K),
//! - fast — `U^fast = (S^T C)† (S^T K S) (C^T S)†` (eq. 5, Algorithm 1).
//!
//! The fast model with a column-selection `S` and the `P ⊂ S` trick
//! (Corollary 5) assembles `S^T K S` from the rows of `C` it already has
//! plus one `(s-c) x (s-c)` oracle block — exactly the paper's Table 3
//! "#entries = nc + (s-c)^2" accounting, which the tests verify through the
//! oracle's entry counter.
//!
//! This module owns the model *math* (the algorithm configs and the
//! unified builders); **how** a build traverses the kernel — materialized,
//! streamed, or through the tile residency layer — is an
//! [`ExecPolicy`](crate::exec::ExecPolicy), and the public entry points
//! live in [`exec`](crate::exec) ([`exec::nystrom`](crate::exec::nystrom),
//! [`exec::prototype`](crate::exec::prototype),
//! [`exec::fast`](crate::exec::fast)). The per-policy functions that used
//! to live here remain as deprecated shims.

pub mod adversarial;
pub mod shift;

use crate::coordinator::oracle::KernelOracle;
use crate::linalg::{gemm, guarded_pinv, pinv, solve, Matrix};
use crate::obs::{self, Stage};
use crate::sketch::{self, SketchKind, SketchOp};
use crate::stream::{
    run_pipeline_validated, CollectConsumer, ConjugateFold, LeverageFold, LeverageSampler,
    OracleColumnsSource, Precision, PrototypeUFold, ResidencyConfig, ResidencyStats,
    ResidentSource, RowGather, SketchFold, StreamConfig, StreamingOracle, TileConsumer,
    TileSource,
};
use crate::util::{Rng, Stopwatch};

/// A low-rank SPSD approximation `K ≈ C U C^T`.
#[derive(Debug, Clone)]
pub struct SpsdApprox {
    /// n x c sketch.
    pub c: Matrix,
    /// c x c symmetric U matrix.
    pub u: Matrix,
    /// Column indices behind `C` (when `P` was a column selection).
    pub p_indices: Vec<usize>,
    /// Which model produced this ("nystrom" | "prototype" | "fast[...]").
    pub method: String,
    /// Kernel entries the oracle served while building this approximation.
    pub entries_observed: u64,
    /// Wall-clock seconds spent building C and U.
    pub build_secs: f64,
}

impl SpsdApprox {
    /// Materialize the full `C U C^T` (small-n evaluation only). U is
    /// symmetric, so the triangular product halves the dominant n x n gemm.
    pub fn materialize(&self) -> Matrix {
        gemm::symm_nt(&self.c.matmul(&self.u), &self.c)
    }

    /// `‖K - C U C^T‖_F^2 / ‖K‖_F^2` against an explicit K.
    pub fn rel_fro_error(&self, k: &Matrix) -> f64 {
        k.sub(&self.materialize()).fro_norm_sq() / k.fro_norm_sq()
    }

    /// Top-k eigenpairs of `C U C^T` in O(n c^2) (Lemma 10).
    pub fn eig_k(&self, k: usize) -> (Vec<f64>, Matrix) {
        solve::eig_k_of_cuc(&self.c, &self.u, k)
    }

    /// Solve `(C U C^T + alpha I) w = y` in O(n c^2) (Lemma 11).
    pub fn solve_regularized(&self, alpha: f64, y: &[f64]) -> Vec<f64> {
        solve::woodbury_solve(&self.c, &self.u, alpha, y)
    }
}

/// Sample `c` distinct columns uniformly (the paper's default P).
pub fn uniform_p(n: usize, c: usize, rng: &mut Rng) -> Vec<usize> {
    let mut idx = rng.sample_without_replacement(n, c.min(n));
    idx.sort_unstable();
    idx
}

/// Build `C = K[:, P]` and optionally gather `C[rows, :]` in the same
/// pass. The whole-tile config takes the direct `columns` path
/// (bit-identical to the historical materialized build); tiled configs run
/// the bounded double-buffered pipeline, so peak extra memory beyond `C`
/// itself is `O(tile_rows · c)`.
fn build_c_panel(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    stream_cfg: StreamConfig,
    gather: Option<&[usize]>,
) -> (Matrix, Option<Matrix>) {
    let n = oracle.n();
    if stream_cfg.is_whole(n) && stream_cfg.precision == Precision::F64 {
        let c = oracle.columns(p_idx);
        let g = gather.map(|idx| c.select_rows(idx));
        return (c, g);
    }
    let src = OracleColumnsSource::new(oracle, p_idx);
    collect_via(&src, stream_cfg, gather)
}

/// Pipeline-only variant of [`build_c_panel`] over an arbitrary source —
/// the entry point the residency-routed builds share (the source is
/// already a [`ResidentSource`] there, so the materialized `columns`
/// shortcut must not bypass it).
fn collect_via(
    src: &dyn TileSource,
    stream_cfg: StreamConfig,
    gather: Option<&[usize]>,
) -> (Matrix, Option<Matrix>) {
    let n = src.rows();
    let width = src.cols();
    let t = stream_cfg.effective_tile_rows(n);
    let mut collect = CollectConsumer::new(n, width);
    match gather {
        None => {
            run_pipeline_validated(
                src,
                t,
                stream_cfg.queue_depth,
                stream_cfg.precision,
                stream_cfg.validate,
                &mut [&mut collect],
            )
            .unwrap_or_else(|e| panic!("{e}"));
            (collect.into_matrix(), None)
        }
        Some(idx) => {
            let mut g = RowGather::new(idx.to_vec(), width);
            run_pipeline_validated(
                src,
                t,
                stream_cfg.queue_depth,
                stream_cfg.precision,
                stream_cfg.validate,
                &mut [&mut collect, &mut g],
            )
            .unwrap_or_else(|e| panic!("{e}"));
            (collect.into_matrix(), Some(g.into_matrix()))
        }
    }
}

/// The `C`-panel pass of a build: either straight off the oracle (with
/// the whole-tile materialized shortcut) or through a [`ResidentSource`]
/// so later passes reload tiles instead of re-paying the oracle.
fn collect_c(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    stream_cfg: StreamConfig,
    resident: Option<&ResidentSource<'_>>,
    gather: Option<&[usize]>,
) -> (Matrix, Option<Matrix>) {
    match resident {
        Some(r) => collect_via(r, stream_cfg, gather),
        None => build_c_panel(oracle, p_idx, stream_cfg, gather),
    }
}

/// Unified Nyström builder: `U = (P^T C)† = W†`, observing only the
/// `n x c` column block. `C` is collected and `W = C[P, :]` gathered in
/// one pass — materialized, streamed, or resident, the results are
/// bit-identical (pure gathers). The non-deprecated entry point is
/// [`exec::nystrom`](crate::exec::nystrom).
pub(crate) fn run_nystrom(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    stream_cfg: StreamConfig,
    residency: Option<&ResidencyConfig>,
) -> (SpsdApprox, Option<ResidencyStats>) {
    let sw = Stopwatch::start();
    let before = oracle.entries_observed();
    let src = OracleColumnsSource::new(oracle, p_idx);
    let resident = residency.map(|rc| ResidentSource::new(&src, rc));
    let (c, w) = collect_c(oracle, p_idx, stream_cfg, resident.as_ref(), Some(p_idx));
    let w = w.expect("gather requested");
    let mut u = {
        let _s = obs::span(Stage::SolveSvd);
        // conditioned core solve: bit-identical to pinv(&w) on healthy W,
        // ladder-regularized (and noted in RunMeta::numeric_health) on
        // degenerate landmark draws
        guarded_pinv(&w)
    };
    u.symmetrize();
    let approx = SpsdApprox {
        c,
        u,
        p_indices: p_idx.to_vec(),
        method: "nystrom".into(),
        entries_observed: oracle.entries_observed() - before,
        build_secs: sw.secs(),
    };
    let stats = resident.map(|r| r.stats());
    (approx, stats)
}

/// Unified prototype builder: `U* = C† K (C†)^T`, observing all `n²`
/// entries (the model's defining cost). With a whole-tile config this is
/// the historical materialized path; tiled configs fold
/// `U = Σ_t C†[:, t] (K_t (C†)^T)` one row-tile at a time — peak extra
/// memory `O(tile_rows · n + c²)` instead of `O(n²)`, matching the
/// materialized result up to reduction reordering (≤1e-12 relative).
/// The non-deprecated entry point is
/// [`exec::prototype`](crate::exec::prototype).
pub(crate) fn run_prototype(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    stream_cfg: StreamConfig,
) -> SpsdApprox {
    let sw = Stopwatch::start();
    let before = oracle.entries_observed();
    let n = oracle.n();
    let (c, _) = build_c_panel(oracle, p_idx, stream_cfg, None);
    let cp = {
        let _s = obs::span(Stage::SolveSvd);
        guarded_pinv(&c) // c x n
    };
    let u = if stream_cfg.is_whole(n) && stream_cfg.precision == Precision::F64 {
        let k = oracle.full();
        // (C† K)(C†)^T is symmetric (K is): triangular product + mirror
        // gives an exactly symmetric U at ~half the flops of the full gemm.
        gemm::symm_nt(&cp.matmul(&k), &cp)
    } else {
        let so = StreamingOracle::new(oracle, stream_cfg);
        let mut fold = PrototypeUFold::new(&cp);
        so.stream_full(&mut [&mut fold]);
        fold.into_matrix()
    };
    SpsdApprox {
        c,
        u,
        p_indices: p_idx.to_vec(),
        method: "prototype".into(),
        entries_observed: oracle.entries_observed() - before,
        build_secs: sw.secs(),
    }
}

/// How the leverage family estimates the row-leverage scores of `C`
/// (Gittens & Mahoney 1303.1849 — leverage sampling is what closes the
/// accuracy gap over uniform Nyström; the estimator decides what that
/// accuracy costs in memory).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeverageBasis {
    /// Exact scores from the `c x c` Gram `C^T C`, folded row-by-row while
    /// the `C` tiles stream (default): `O(c²)` score state, bit-identical
    /// results for every tile size.
    Gram,
    /// Sketched Gram surrogate `C^T Ω Ω^T C` from an SRHT `Ω` with `m`
    /// rows, folded in the same pass (`m ≈ 4c` is a good default; `(1±ε)`
    /// scores once `Ω` embeds col(C)). Deterministic per seed, but its
    /// reductions regroup by tile, so streamed results match the
    /// materialized path only to reduction-reordering tolerance.
    Sketched { m: usize },
    /// Reference path: SVD of the resident `C` — the historical behavior,
    /// kept as the accuracy baseline. Needs `O(n·c)` scratch, which is
    /// exactly what the streamed estimators exist to avoid.
    ExactSvd,
}

/// Configuration for the fast model's sketching matrix S.
#[derive(Debug, Clone, Copy)]
pub struct FastConfig {
    /// Target sketch size s (expected, for probabilistic sampling).
    pub s: usize,
    /// Sketching family for S.
    pub kind: SketchKind,
    /// Enforce `P ⊂ S` (Corollary 5; on by default — it both improves
    /// accuracy and enables the (s-c)^2 entry count).
    pub force_p_in_s: bool,
    /// Score estimator for `SketchKind::Leverage` (ignored otherwise).
    pub leverage_basis: LeverageBasis,
}

impl FastConfig {
    pub fn uniform(s: usize) -> Self {
        FastConfig {
            s,
            kind: SketchKind::Uniform,
            force_p_in_s: true,
            leverage_basis: LeverageBasis::Gram,
        }
    }

    pub fn leverage(s: usize) -> Self {
        // Unscaled by default: the paper (§4.5) reports scaling hurts
        // numerical stability in practice.
        FastConfig {
            s,
            kind: SketchKind::Leverage { scaled: false },
            force_p_in_s: true,
            leverage_basis: LeverageBasis::Gram,
        }
    }

    /// Override the leverage score estimator.
    pub fn with_basis(mut self, basis: LeverageBasis) -> Self {
        self.leverage_basis = basis;
        self
    }
}

/// Unified fast-model builder (Algorithm 1) — the one body behind every
/// execution policy; the non-deprecated entry point is
/// [`exec::fast`](crate::exec::fast).
///
/// For uniform selection one pass over `K[:, P]` collects `C` and gathers
/// `C[S, :]` (everything `S^T C` and `S^T K S` need besides the `(s-c)²`
/// fresh oracle block), so peak extra memory beyond the `C` output is
/// `O(tile_rows · c + s²)`. Leverage selection (default
/// [`LeverageBasis::Gram`]) folds its `O(c²)` score state while the tiles
/// stream; without residency the same pass also collects `C` and the
/// sampler then sweeps the resident panel, while **with** residency the
/// build becomes a genuine two-pass plan over the source — pass 1 folds
/// only the score state while tiles write through the LRU/spill arena,
/// pass 2 reloads tiles (never the oracle) to collect `C`, score, draw
/// and gather `C[S, :]` in one sweep, so the oracle is charged exactly
/// one `n·c` at any RAM budget. The rng call sequence is identical either
/// way and the sampler is tile-order invariant, so results are
/// **bit-identical** across policies (asserted in `tests/exec_api.rs`).
/// Projection sketches fold `S^T C` during the `C` pass and `S^T K S`
/// over full-K row tiles — still observing `n²` entries (Table 4) but
/// never storing them; they have no reloadable working set, so `residency`
/// must be `None` for them (the exec layer strips it).
pub(crate) fn run_fast(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    cfg: FastConfig,
    stream_cfg: StreamConfig,
    residency: Option<&ResidencyConfig>,
    rng: &mut Rng,
) -> (SpsdApprox, Option<ResidencyStats>) {
    let sw = Stopwatch::start();
    let before = oracle.entries_observed();
    let n = oracle.n();
    let src = OracleColumnsSource::new(oracle, p_idx);
    let resident = residency.map(|rc| ResidentSource::new(&src, rc));

    let (c_mat, stc, sks) = match cfg.kind {
        SketchKind::Uniform => {
            // S doesn't depend on C: draw it up front so C[S, :] is
            // gathered in the same pass that builds C.
            let op = build_selection_sketch(None, p_idx, cfg, n, rng);
            let (indices, scales) = select_parts(&op);
            let (c_mat, rows_s) =
                collect_c(oracle, p_idx, stream_cfg, resident.as_ref(), Some(&indices));
            let rows_s = rows_s.expect("gather requested");
            let stc = scale_rows(&rows_s, &scales);
            let sks = assemble_sks(oracle, &rows_s, p_idx, &indices, &scales);
            (c_mat, stc, sks)
        }
        SketchKind::Leverage { scaled } => match cfg.leverage_basis {
            LeverageBasis::ExactSvd => {
                // Reference path (the historical behavior): one pass builds
                // C, then scores come from an SVD of the resident panel —
                // `O(n·c)` scratch the streamed estimators avoid.
                let (c_mat, _) =
                    collect_c(oracle, p_idx, stream_cfg, resident.as_ref(), None);
                let op = build_selection_sketch(Some(&c_mat), p_idx, cfg, n, rng);
                let (indices, scales) = select_parts(&op);
                let rows_s = c_mat.select_rows(&indices);
                let stc = scale_rows(&rows_s, &scales);
                let sks = assemble_sks(oracle, &rows_s, p_idx, &indices, &scales);
                (c_mat, stc, sks)
            }
            basis => {
                // Streamed score estimators: the O(c²) leverage state
                // (row-ordered Gram, or the SRHT surrogate Ω^T C) folds
                // while the C tiles stream, so the score computation never
                // needs the n x c panel at once.
                let t = stream_cfg.effective_tile_rows(n);
                let sk_op;
                let mut fold = match basis {
                    LeverageBasis::Sketched { m } => {
                        sk_op = sketch::srht_sketch(n, m.max(p_idx.len()), rng);
                        LeverageFold::sketched(&sk_op, p_idx.len())
                    }
                    _ => LeverageFold::exact(p_idx.len()),
                };
                // Pass 1. Without residency, collect C in the same pass;
                // with residency, fold only — tiles write through the
                // LRU/spill arena as a side effect, and pass 2 reloads
                // them for free.
                let collected = match resident.as_ref() {
                    None => {
                        let mut collect = CollectConsumer::new(n, p_idx.len());
                        let so = StreamingOracle::new(oracle, stream_cfg);
                        so.stream_columns(p_idx, &mut [&mut collect, &mut fold]);
                        Some(collect.into_matrix())
                    }
                    Some(r) => {
                        run_pipeline_validated(
                            r,
                            t,
                            stream_cfg.queue_depth,
                            stream_cfg.precision,
                            stream_cfg.validate,
                            &mut [&mut fold],
                        )
                        .unwrap_or_else(|e| panic!("{e}"));
                        None
                    }
                };
                let est = fold.into_estimate();

                let s_extra = cfg
                    .s
                    .saturating_sub(if cfg.force_p_in_s { p_idx.len() } else { 0 })
                    .max(1);
                let forced = if cfg.force_p_in_s { p_idx.to_vec() } else { Vec::new() };
                let mut sampler =
                    LeverageSampler::new(&est, s_extra, scaled, forced, n, p_idx.len(), rng);
                // Pass 2: score, draw and gather C[S, :] in one row-order
                // sweep — over the in-memory panel, or over tiles reloaded
                // from residency (zero new oracle entries either way).
                let c_mat = match (resident.as_ref(), collected) {
                    (None, Some(c_mat)) => {
                        sampler.consume(0, &c_mat);
                        c_mat
                    }
                    (Some(r), _) => {
                        let mut collect = CollectConsumer::new(n, p_idx.len());
                        run_pipeline_validated(
                            r,
                            t,
                            stream_cfg.queue_depth,
                            stream_cfg.precision,
                            stream_cfg.validate,
                            &mut [&mut collect, &mut sampler],
                        )
                        .unwrap_or_else(|e| panic!("{e}"));
                        collect.into_matrix()
                    }
                    (None, None) => unreachable!("pass 1 collects when not resident"),
                };
                let (mut indices, mut scales, mut rows_s, sampled) = sampler.into_parts();
                if sampled == 0 {
                    // Degenerate draw (e.g. all-zero scores): one uniform
                    // pick so S is non-empty even without forced indices,
                    // mirroring sketch::leverage — which, like this, may
                    // land inside P, in which case S == P and the build
                    // legitimately degenerates to Nyström for this draw.
                    let pick = rng.usize_below(n);
                    if let Err(pos) = indices.binary_search(&pick) {
                        indices.insert(pos, pick);
                        scales.insert(pos, 1.0);
                        rows_s = c_mat.select_rows(&indices);
                    }
                }
                let stc = scale_rows(&rows_s, &scales);
                let sks = assemble_sks(oracle, &rows_s, p_idx, &indices, &scales);
                (c_mat, stc, sks)
            }
        },
        _ => {
            // Projection sketches need every entry of K (Table 4 —
            // theoretical interest / benchmarking only).
            assert!(
                residency.is_none(),
                "residency routing needs a column-selection sketch, not {}",
                cfg.kind.name()
            );
            let op = sketch::build(cfg.kind, n, cfg.s, None, rng);
            if stream_cfg.is_whole(n) && stream_cfg.precision == Precision::F64 {
                let c_mat = oracle.columns(p_idx);
                let k = oracle.full();
                let stc = op.apply_left(&c_mat);
                let mut sks = op.conjugate(&k);
                sks.symmetrize();
                (c_mat, stc, sks)
            } else {
                let so = StreamingOracle::new(oracle, stream_cfg);
                let mut collect = CollectConsumer::new(n, p_idx.len());
                let mut stc_fold = SketchFold::new(&op, p_idx.len());
                so.stream_columns(p_idx, &mut [&mut collect, &mut stc_fold]);
                let mut sks_fold = ConjugateFold::new(&op);
                so.stream_full(&mut [&mut sks_fold]);
                (collect.into_matrix(), stc_fold.into_matrix(), sks_fold.into_matrix())
            }
        }
    };

    let stc_pinv = {
        let _s = obs::span(Stage::SolveSvd);
        guarded_pinv(&stc) // c x s
    };
    // (S^T C)† (S^T K S) ((S^T C)†)^T is symmetric since S^T K S is.
    let u = gemm::symm_nt(&stc_pinv.matmul(&sks), &stc_pinv);
    let approx = SpsdApprox {
        c: c_mat,
        u,
        p_indices: p_idx.to_vec(),
        method: format!("fast[{}]", cfg.kind.name()),
        entries_observed: oracle.entries_observed() - before,
        build_secs: sw.secs(),
    };
    let stats = resident.map(|r| r.stats());
    (approx, stats)
}

// ---------------------------------------------------------------------------
// Deprecated per-policy shims. The one policy-carrying surface is
// `exec`; these forward to the unified builders and will be removed.
// ---------------------------------------------------------------------------

/// The Nyström method on the materialized path.
#[deprecated(note = "use `exec::nystrom` with `ExecPolicy::Materialized`")]
pub fn nystrom(oracle: &dyn KernelOracle, p_idx: &[usize]) -> SpsdApprox {
    run_nystrom(oracle, p_idx, StreamConfig::whole(), None).0
}

/// Nyström through the tile pipeline.
#[deprecated(note = "use `exec::nystrom` with `ExecPolicy::Streamed`")]
pub fn nystrom_streamed(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    stream_cfg: StreamConfig,
) -> SpsdApprox {
    run_nystrom(oracle, p_idx, stream_cfg, None).0
}

/// Nyström through the tile residency layer.
#[deprecated(note = "use `exec::nystrom` with `ExecPolicy::Resident`")]
pub fn nystrom_resident(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    stream_cfg: StreamConfig,
    residency: &ResidencyConfig,
) -> (SpsdApprox, ResidencyStats) {
    let (approx, stats) = run_nystrom(oracle, p_idx, stream_cfg, Some(residency));
    (approx, stats.expect("residency stats"))
}

/// The prototype model on the materialized path.
#[deprecated(note = "use `exec::prototype` with `ExecPolicy::Materialized`")]
pub fn prototype(oracle: &dyn KernelOracle, p_idx: &[usize]) -> SpsdApprox {
    run_prototype(oracle, p_idx, StreamConfig::whole())
}

/// Prototype model through the tile pipeline.
#[deprecated(note = "use `exec::prototype` with `ExecPolicy::Streamed`")]
pub fn prototype_streamed(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    stream_cfg: StreamConfig,
) -> SpsdApprox {
    run_prototype(oracle, p_idx, stream_cfg)
}

/// The fast SPSD approximation model on the materialized path.
#[deprecated(note = "use `exec::fast` with `ExecPolicy::Materialized`")]
pub fn fast(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    cfg: FastConfig,
    rng: &mut Rng,
) -> SpsdApprox {
    run_fast(oracle, p_idx, cfg, StreamConfig::whole(), None, rng).0
}

/// The fast model through the tile pipeline.
#[deprecated(note = "use `exec::fast` with `ExecPolicy::Streamed`")]
pub fn fast_streamed(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    cfg: FastConfig,
    stream_cfg: StreamConfig,
    rng: &mut Rng,
) -> SpsdApprox {
    run_fast(oracle, p_idx, cfg, stream_cfg, None, rng).0
}

/// The fast model through the tile residency layer (column-selection
/// sketches only).
#[deprecated(note = "use `exec::fast` with `ExecPolicy::Resident`")]
pub fn fast_streamed_resident(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    cfg: FastConfig,
    stream_cfg: StreamConfig,
    residency: &ResidencyConfig,
    rng: &mut Rng,
) -> (SpsdApprox, ResidencyStats) {
    let (approx, stats) = run_fast(oracle, p_idx, cfg, stream_cfg, Some(residency), rng);
    (approx, stats.expect("residency stats"))
}

/// Clone out the index/scale arrays of a column-selection sketch.
pub(crate) fn select_parts(op: &SketchOp) -> (Vec<usize>, Vec<f64>) {
    match op {
        SketchOp::Select { indices, scales, .. } => (indices.clone(), scales.clone()),
        _ => unreachable!("selection sketch expected"),
    }
}

/// `diag(scales) · rows` — the `S^T C` of a column-selection sketch given
/// the already-gathered rows `C[S, :]`. Matches `SketchOp::apply_left`
/// bit-for-bit (same gather, same in-place scaling).
pub(crate) fn scale_rows(rows_s: &Matrix, scales: &[f64]) -> Matrix {
    let mut out = rows_s.clone();
    for (r, &sc) in scales.iter().enumerate() {
        if sc != 1.0 {
            for v in out.row_mut(r) {
                *v *= sc;
            }
        }
    }
    out
}

/// Build the column-selection S for the fast model, honoring `P ⊂ S`.
/// `c_mat` is only consulted for leverage-score sampling.
pub(crate) fn build_selection_sketch(
    c_mat: Option<&Matrix>,
    p_idx: &[usize],
    cfg: FastConfig,
    n: usize,
    rng: &mut Rng,
) -> SketchOp {
    let extra = cfg.s.saturating_sub(if cfg.force_p_in_s { p_idx.len() } else { 0 });
    let op = match cfg.kind {
        SketchKind::Uniform => {
            // Paper §4.5: sample from [n] \ P, then union with P. Unscaled —
            // matching the no-scaling stability trick used for the figures.
            sketch::uniform(n, extra.max(1), false, rng)
        }
        SketchKind::Leverage { scaled } => {
            let scores = sketch::leverage_scores(c_mat.expect("leverage sampling needs C"));
            sketch::leverage(&scores, extra.max(1), scaled, rng)
        }
        _ => unreachable!(),
    };
    if cfg.force_p_in_s {
        sketch::with_forced_indices(op, p_idx)
    } else {
        op
    }
}

/// `S^T K S` for a column-selection S over index set `indices`, reusing the
/// gathered rows `c_s = C[S, :]` for every (i, j) pair where j ∈ P:
/// `K[s_i, p_j] = C[s_i, j] = c_s[i, j]`. Only the `(S \ P) x (S \ P)`
/// block touches the oracle — and only the `s x c` gather (not the full
/// `n x c` panel) is needed here, which is what lets the streamed build
/// drop `C` tiles as soon as they are folded.
pub(crate) fn assemble_sks(
    oracle: &dyn KernelOracle,
    c_s: &Matrix,
    p_idx: &[usize],
    indices: &[usize],
    scales: &[f64],
) -> Matrix {
    let s = indices.len();
    debug_assert_eq!((c_s.rows(), c_s.cols()), (s, p_idx.len()));
    // position of each p in the C columns
    let col_of: std::collections::HashMap<usize, usize> =
        p_idx.iter().enumerate().map(|(j, &p)| (p, j)).collect();
    let mut out = Matrix::zeros(s, s);
    // rows/cols of S covered by C: K[s_r, p] = c_s[r, col_of(p)]
    let in_p: Vec<Option<usize>> = indices.iter().map(|i| col_of.get(i).copied()).collect();
    let fresh: Vec<usize> = (0..s).filter(|&j| in_p[j].is_none()).collect();
    // (a) columns in P (and by symmetry rows in P) come from the gather
    for r in 0..s {
        for (cc, &jpos) in in_p.iter().enumerate() {
            if let Some(cj) = jpos {
                out[(r, cc)] = c_s[(r, cj)];
            }
        }
    }
    for (r, &rpos) in in_p.iter().enumerate() {
        if let Some(cr) = rpos {
            for cc in 0..s {
                out[(r, cc)] = c_s[(cc, cr)];
            }
        }
    }
    // (b) the fresh block needs the oracle
    if !fresh.is_empty() {
        let fresh_idx: Vec<usize> = fresh.iter().map(|&j| indices[j]).collect();
        let block = {
            let _s = obs::span(Stage::OracleTile);
            oracle.block(&fresh_idx, &fresh_idx)
        };
        for (bi, &r) in fresh.iter().enumerate() {
            for (bj, &cc) in fresh.iter().enumerate() {
                out[(r, cc)] = block[(bi, bj)];
            }
        }
    }
    // (c) apply scales: out[i, j] *= scale_i * scale_j
    for i in 0..s {
        if scales[i] != 1.0 {
            let si = scales[i];
            for v in out.row_mut(i) {
                *v *= si;
            }
        }
    }
    for j in 0..s {
        if scales[j] != 1.0 {
            let sj = scales[j];
            for i in 0..s {
                out[(i, j)] *= sj;
            }
        }
    }
    out.symmetrize();
    out
}

/// `min_U ‖K - C U C^T‖_F^2` — the prototype model's objective value, used
/// as the baseline in Theorem 3 style comparisons.
pub fn optimal_objective(k: &Matrix, c: &Matrix) -> f64 {
    let cp = pinv(c);
    let u = gemm::symm_nt(&cp.matmul(k), &cp);
    k.sub(&gemm::symm_nt(&c.matmul(&u), c)).fro_norm_sq()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::oracle::DenseOracle;
    use crate::exec::{self, ExecPolicy};
    use crate::testkit::gen;

    fn spsd_oracle(n: usize, rank: usize, seed: u64) -> DenseOracle {
        let mut rng = Rng::new(seed);
        DenseOracle::new(gen::spsd(&mut rng, n, rank))
    }

    // Materialized-policy helpers: the figures-style call shape.
    fn nystrom_m(o: &dyn KernelOracle, p: &[usize]) -> SpsdApprox {
        exec::nystrom(o, p, &ExecPolicy::Materialized).result
    }

    fn prototype_m(o: &dyn KernelOracle, p: &[usize]) -> SpsdApprox {
        exec::prototype(o, p, &ExecPolicy::Materialized).result
    }

    fn fast_m(o: &dyn KernelOracle, p: &[usize], cfg: FastConfig, rng: &mut Rng) -> SpsdApprox {
        exec::fast(o, p, cfg, &ExecPolicy::Materialized, rng).result
    }

    #[test]
    fn nystrom_entries_and_shape() {
        let o = spsd_oracle(30, 30, 0);
        let mut rng = Rng::new(1);
        let p = uniform_p(30, 6, &mut rng);
        let a = nystrom_m(&o, &p);
        assert_eq!((a.c.rows(), a.c.cols()), (30, 6));
        assert_eq!((a.u.rows(), a.u.cols()), (6, 6));
        assert_eq!(a.entries_observed, 30 * 6);
    }

    #[test]
    fn nystrom_report_carries_uniform_accounting() {
        let o = spsd_oracle(30, 30, 0);
        let mut rng = Rng::new(1);
        let p = uniform_p(30, 6, &mut rng);
        let rep = exec::nystrom(&o, &p, &ExecPolicy::Materialized);
        assert_eq!(rep.meta.entries, Some(rep.result.entries_observed));
        assert!(rep.meta.residency.is_none());
        assert!(rep.meta.predicted_peak_bytes.unwrap() >= (30 * 6 * 8) as u64);
        assert!(rep.meta.compute_secs >= 0.0);
    }

    #[test]
    fn prototype_observes_everything_and_is_optimal() {
        let o = spsd_oracle(25, 25, 2);
        let mut rng = Rng::new(3);
        let p = uniform_p(25, 5, &mut rng);
        let a = prototype_m(&o, &p);
        assert_eq!(a.entries_observed, 25 * 25 + 25 * 5);
        // prototype attains min_U objective
        let err = o.inner().sub(&a.materialize()).fro_norm_sq();
        let opt = optimal_objective(o.inner(), &a.c);
        assert!((err - opt).abs() < 1e-6 * opt.max(1e-9), "err={err} opt={opt}");
    }

    #[test]
    fn fast_entry_count_matches_table3() {
        let n = 40;
        let o = spsd_oracle(n, n, 4);
        let mut rng = Rng::new(5);
        let c = 5;
        let p = uniform_p(n, c, &mut rng);
        let a = fast_m(&o, &p, FastConfig::uniform(15), &mut rng);
        // entries = n*c (columns) + (s'-c)^2 (fresh block), s' = |S|
        let s_len = {
            // recover |S| from U's construction: entries formula inversion
            let fresh_sq = a.entries_observed - (n * c) as u64;
            (fresh_sq as f64).sqrt() as u64 + c as u64
        };
        assert!(s_len >= c as u64);
        let fresh = s_len - c as u64;
        assert_eq!(a.entries_observed, (n * c) as u64 + fresh * fresh);
        // far fewer than the prototype's n^2
        assert!(a.entries_observed < (n * n) as u64);
    }

    #[test]
    fn fast_error_between_nystrom_and_prototype() {
        // On a decaying-spectrum SPSD matrix, fast (s=4c) should be much
        // closer to prototype than Nyström is, and never worse than ~Nyström.
        let n = 80;
        let mut rng = Rng::new(6);
        // decaying spectrum: G diag(1/i^2) G^T
        let g = crate::linalg::qr::qr_thin(&Matrix::randn(n, n, &mut rng)).q;
        let vals: Vec<f64> = (1..=n).map(|i| 1.0 / (i as f64).powi(2)).collect();
        let gd = Matrix::from_fn(n, n, |i, j| g[(i, j)] * vals[j]);
        let k = gd.matmul_tr(&g);
        let o = DenseOracle::new(k.clone());
        let c = 8;
        let mut err_ny = 0.0;
        let mut err_fast = 0.0;
        let mut err_proto = 0.0;
        let trials = 5;
        for t in 0..trials {
            let mut r = Rng::new(100 + t);
            let p = uniform_p(n, c, &mut r);
            err_ny += nystrom_m(&o, &p).rel_fro_error(&k);
            err_fast += fast_m(&o, &p, FastConfig::uniform(4 * c), &mut r).rel_fro_error(&k);
            err_proto += prototype_m(&o, &p).rel_fro_error(&k);
        }
        err_ny /= trials as f64;
        err_fast /= trials as f64;
        err_proto /= trials as f64;
        assert!(err_proto <= err_fast + 1e-9, "prototype optimal: {err_proto} vs {err_fast}");
        assert!(
            err_fast <= err_ny * 1.05 + 1e-9,
            "fast ({err_fast}) should not be materially worse than nystrom ({err_ny})"
        );
    }

    #[test]
    fn fast_equals_nystrom_when_s_is_p() {
        // S = P (no extra columns, force_p) reduces the fast model to Nyström.
        let o = spsd_oracle(30, 8, 7);
        let mut rng = Rng::new(8);
        let p = uniform_p(30, 6, &mut rng);
        let mut rng2 = Rng::new(9);
        let a_fast = fast_m(&o, &p, FastConfig::uniform(p.len()), &mut rng2);
        let a_ny = nystrom_m(&o, &p);
        // rank(K)=8 > c=6 so neither is exact, but on the shared subspace
        // both satisfy the same fixed-point equation; check shapes + rough
        // agreement of errors.
        let k = o.inner();
        let e_f = a_fast.rel_fro_error(k);
        let e_n = a_ny.rel_fro_error(k);
        assert!(e_f <= e_n * 1.5 + 1e-9, "fast {e_f} vs nystrom {e_n}");
    }

    #[test]
    fn exact_recovery_when_rank_c_equals_rank_k() {
        // Theorem 6: rank(K) = rank(C) => fast model recovers K exactly.
        let n = 40;
        let r = 5;
        let o = spsd_oracle(n, r, 10);
        let mut rng = Rng::new(11);
        // c > r columns uniformly: C almost surely has rank r = rank(K)
        let p = uniform_p(n, 2 * r, &mut rng);
        for cfg in [FastConfig::uniform(3 * r), FastConfig::leverage(3 * r)] {
            let a = fast_m(&o, &p, cfg, &mut rng);
            let err = a.rel_fro_error(o.inner());
            assert!(err < 1e-10, "{}: rel err {err}", a.method);
        }
        // Nyström and prototype also recover exactly (known property)
        assert!(nystrom_m(&o, &p).rel_fro_error(o.inner()) < 1e-10);
        assert!(prototype_m(&o, &p).rel_fro_error(o.inner()) < 1e-10);
    }

    #[test]
    fn leverage_bases_all_recover_low_rank() {
        // Theorem 6 holds for any S ⊇ P with rank(S^T C) = rank(C), so all
        // three score estimators must recover a low-rank K exactly —
        // including the sketched surrogate, whatever its score noise.
        let n = 40;
        let r = 5;
        let o = spsd_oracle(n, r, 30);
        let mut rng = Rng::new(31);
        let p = uniform_p(n, 2 * r, &mut rng);
        for basis in [
            LeverageBasis::Gram,
            LeverageBasis::Sketched { m: 40 },
            LeverageBasis::ExactSvd,
        ] {
            let cfg = FastConfig::leverage(3 * r).with_basis(basis);
            let a = fast_m(&o, &p, cfg, &mut rng);
            let err = a.rel_fro_error(o.inner());
            assert!(err < 1e-8, "{basis:?}: rel err {err}");
        }
    }

    #[test]
    fn projection_sketches_work_and_observe_n2() {
        let n = 30;
        let o = spsd_oracle(n, 4, 12);
        let mut rng = Rng::new(13);
        let p = uniform_p(n, 8, &mut rng);
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            o.reset_entries();
            let cfg = FastConfig {
                s: 20,
                kind,
                force_p_in_s: false,
                leverage_basis: LeverageBasis::Gram,
            };
            let a = fast_m(&o, &p, cfg, &mut rng);
            let err = a.rel_fro_error(o.inner());
            assert!(err < 1e-8, "{}: err {err}", kind.name());
            assert!(a.entries_observed >= (n * n) as u64, "{} needs full K", kind.name());
        }
    }

    #[test]
    fn streamed_builds_match_materialized_on_dense_oracle() {
        // Gather-based paths (uniform/leverage fast, nystrom) are
        // bit-identical to the materialized build for every tile size;
        // prototype matches up to reduction reordering.
        let n = 53; // deliberately not divisible by the tile sizes
        let o = spsd_oracle(n, 9, 20);
        let mut rng = Rng::new(21);
        let p = uniform_p(n, 8, &mut rng);
        for tile in [1usize, 7, 16, n] {
            let policy = ExecPolicy::streamed(tile);
            let cfgs = [FastConfig::uniform(20), FastConfig::leverage(20)];
            for cfg in cfgs {
                let mut r1 = Rng::new(99);
                let mut r2 = Rng::new(99);
                let a = fast_m(&o, &p, cfg, &mut r1);
                let b = exec::fast(&o, &p, cfg, &policy, &mut r2).result;
                assert_eq!(a.c.max_abs_diff(&b.c), 0.0, "{} C tile={tile}", a.method);
                assert_eq!(a.u.max_abs_diff(&b.u), 0.0, "{} U tile={tile}", a.method);
                assert_eq!(a.entries_observed, b.entries_observed, "{} entries", a.method);
            }
            let a = nystrom_m(&o, &p);
            let b = exec::nystrom(&o, &p, &policy).result;
            assert_eq!(a.c.max_abs_diff(&b.c), 0.0);
            assert_eq!(a.u.max_abs_diff(&b.u), 0.0);

            let a = prototype_m(&o, &p);
            let b = exec::prototype(&o, &p, &policy).result;
            assert_eq!(a.c.max_abs_diff(&b.c), 0.0);
            let scale = a.u.fro_norm().max(1e-12);
            assert!(
                b.u.sub(&a.u).fro_norm() / scale < 1e-12,
                "prototype U tile={tile}"
            );
            assert_eq!(a.entries_observed, b.entries_observed);
        }
    }

    #[test]
    fn streamed_projection_sketches_match_within_tolerance() {
        let n = 34;
        let o = spsd_oracle(n, 5, 22);
        let p = uniform_p(n, 7, &mut Rng::new(23));
        for kind in [SketchKind::Gaussian, SketchKind::CountSketch, SketchKind::Srht] {
            let cfg = FastConfig {
                s: 18,
                kind,
                force_p_in_s: false,
                leverage_basis: LeverageBasis::Gram,
            };
            let a = fast_m(&o, &p, cfg, &mut Rng::new(55));
            let b = exec::fast(&o, &p, cfg, &ExecPolicy::streamed(9), &mut Rng::new(55)).result;
            let k = o.inner();
            let diff = a.materialize().sub(&b.materialize()).fro_norm() / k.fro_norm();
            assert!(diff < 1e-10, "{}: {diff}", kind.name());
            assert!(b.entries_observed >= (n * n) as u64, "{} must observe n²", kind.name());
        }
    }

    #[test]
    fn resident_projection_sketch_falls_back_without_stats() {
        // Projection sketches stream the full K — no reloadable working
        // set. A Resident policy must degrade to plain streaming (no
        // panic), with `residency: None` in the report.
        let o = spsd_oracle(30, 4, 12);
        let p = uniform_p(30, 6, &mut Rng::new(1));
        let cfg = FastConfig {
            s: 15,
            kind: SketchKind::Gaussian,
            force_p_in_s: false,
            leverage_basis: LeverageBasis::Gram,
        };
        let rep = exec::fast(&o, &p, cfg, &ExecPolicy::resident(0).with_tile_rows(8), &mut Rng::new(2));
        assert!(rep.meta.residency.is_none());
        let plain = exec::fast(&o, &p, cfg, &ExecPolicy::streamed(8), &mut Rng::new(2)).result;
        assert_eq!(rep.result.u.max_abs_diff(&plain.u), 0.0);
    }

    #[test]
    fn eig_k_and_solve_work_through_approx() {
        let o = spsd_oracle(35, 6, 14);
        let mut rng = Rng::new(15);
        let p = uniform_p(35, 12, &mut rng);
        let a = fast_m(&o, &p, FastConfig::uniform(24), &mut rng);
        let (vals, vecs) = a.eig_k(3);
        assert_eq!(vals.len(), 3);
        assert_eq!((vecs.rows(), vecs.cols()), (35, 3));
        // exact recovery (rank 6 < c) ⇒ eigenvalues match K's
        let ek = crate::linalg::eigh(o.inner());
        for i in 0..3 {
            assert!((vals[i] - ek.values[i]).abs() < 1e-6 * ek.values[0]);
        }
        let y: Vec<f64> = (0..35).map(|i| (i as f64).sin()).collect();
        let w = a.solve_regularized(0.5, &y);
        // check residual of the solve against materialized system
        let mut kk = a.materialize();
        for i in 0..35 {
            kk[(i, i)] += 0.5;
        }
        let resid: f64 = kk
            .matvec(&w)
            .iter()
            .zip(&y)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        assert!(resid < 1e-12, "resid={resid}");
    }
}
