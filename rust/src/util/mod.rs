//! Small shared utilities: RNG, timing, content hashing.

pub mod hash;
pub mod rng;
pub mod timer;

pub use hash::xxh64;
pub use rng::Rng;
pub use timer::Stopwatch;
