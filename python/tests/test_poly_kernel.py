"""Polynomial-kernel Pallas tests vs the jnp oracle."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.poly_block import poly_block
from compile.kernels.ref import poly_block_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


def _scalar(v):
    return jnp.full((1, 1), v, dtype=jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    mt=st.integers(1, 2),
    nt=st.integers(1, 2),
    d=st.sampled_from([1, 4, 16]),
    gamma=st.floats(0.1, 2.0),
    coef0=st.floats(0.0, 2.0),
    degree=st.sampled_from([1.0, 2.0, 3.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_poly_block_matches_ref(mt, nt, d, gamma, coef0, degree, seed):
    bm, bn = 8, 8
    x = _rand((mt * bm, d), seed, 0.5)
    y = _rand((nt * bn, d), seed + 1, 0.5)
    out = poly_block(_scalar(gamma), _scalar(coef0), _scalar(degree), x, y, bm=bm, bn=bn)
    ref = poly_block_ref(gamma, coef0, degree, x, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("d", [16, 128])
def test_poly_block_aot_buckets(d):
    x = _rand((256, d), 3, 0.2)
    y = _rand((256, d), 4, 0.2)
    out = poly_block(_scalar(0.5), _scalar(1.0), _scalar(2.0), x, y, bm=128, bn=128)
    ref = poly_block_ref(0.5, 1.0, 2.0, x, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=1e-4)


def test_degree_one_is_affine_gram():
    x = _rand((8, 4), 5)
    y = _rand((8, 4), 6)
    out = poly_block(_scalar(1.0), _scalar(0.0), _scalar(1.0), x, y, bm=8, bn=8)
    ref = np.asarray(x) @ np.asarray(y).T
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-6)


def test_poly_block_in_artifact_specs():
    from compile.model import ARTIFACT_SPECS

    assert "poly_block_256x256x16" in ARTIFACT_SPECS
    fn, shapes = ARTIFACT_SPECS["poly_block_256x256x16"]
    assert shapes == [(1, 1), (1, 1), (1, 1), (256, 16), (256, 16)]
