//! Shard-count invariance matrix (ISSUE 10): the row-sharded execution
//! plane must be an accounting change, not a numeric one. For shard
//! counts {1, 2, 4, 7} against the unsharded streamed run:
//!
//! - **Selection paths** (nystrom, fast[uniform], cur_fast) gather rows
//!   and solve small resident systems — bit-identical across shard
//!   counts, including the degenerate 1-shard wrapper.
//! - **Reduction-regrouped paths** (fast[leverage] on the Gram basis)
//!   merge per-shard partial Gram folds, so sums regroup by shard
//!   boundary: scores agree only to reduction-reordering tolerance
//!   (≤1e-12 relative), while the gathered `C` panel stays bit-identical.
//!
//! Plus the coalescing contract: K same-oracle requests queued behind a
//! gated worker ride ONE stream pass — the oracle is charged exactly one
//! build's entries (measured through the entry counter), every rider
//! reply carries `batched = true`, and the coalescing counters land in
//! the service metrics.
//!
//! Tests that run sharded passes share `SHARD_LOCK`: the worker-death
//! test arms the process-global fault plan, and an armed
//! `ShardWorkerDeath` must not leak into a concurrently running
//! equivalence cell.

use fastspsd::coordinator::oracle::{DenseOracle, KernelOracle, RbfOracle};
use fastspsd::coordinator::{planner, ApproxRequest, ApproxService, MethodSpec, ServiceConfig};
use fastspsd::cur::FastCurConfig;
use fastspsd::exec::{self, ExecPolicy};
use fastspsd::linalg::Matrix;
use fastspsd::spsd::FastConfig;
use fastspsd::stream::Precision;
use fastspsd::testkit::faults::{self, FaultPlan, FaultPoint, FaultSpec};
use fastspsd::util::Rng;
use std::sync::{mpsc, Arc, Condvar, Mutex};

/// Serializes the file's sharded passes against the fault-arming test.
static SHARD_LOCK: Mutex<()> = Mutex::new(());

fn shard_guard() -> std::sync::MutexGuard<'static, ()> {
    SHARD_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

const N: usize = 57;
const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 7];

fn psd_oracle() -> DenseOracle {
    let mut rng = Rng::new(9);
    let g = Matrix::randn(N, 7, &mut rng);
    DenseOracle::new(g.matmul_tr(&g))
}

fn landmarks() -> Vec<usize> {
    vec![1, 8, 19, 30, 44, 55]
}

fn streamed() -> ExecPolicy {
    ExecPolicy::streamed(9)
}

fn sharded(shards: usize) -> ExecPolicy {
    ExecPolicy::sharded(shards, streamed())
}

/// The shard accounting every sharded run must carry: one worker per
/// shard, contiguous ranges covering all n rows, no silent re-execution.
fn assert_shard_meta(meta: &fastspsd::exec::RunMeta, shards: usize) {
    let stats = meta.shard.as_ref().expect("sharded policy carries ShardStats");
    assert_eq!(stats.shards, shards);
    assert_eq!(stats.workers.len(), shards);
    assert_eq!(stats.reexecuted, 0, "no faults armed, no re-execution");
    let mut next = 0;
    for w in &stats.workers {
        assert_eq!(w.r0, next, "shard ranges must be contiguous");
        assert!(w.r1 > w.r0);
        next = w.r1;
    }
    assert_eq!(next, N, "shard ranges must cover every row");
}

#[test]
fn sharded_nystrom_is_bit_identical_across_shard_counts() {
    let _g = shard_guard();
    let o = psd_oracle();
    let p = landmarks();
    let reference = exec::nystrom(&o, &p, &streamed());
    assert!(reference.meta.shard.is_none(), "unsharded runs carry no shard stats");
    for shards in SHARD_COUNTS {
        let rep = exec::nystrom(&o, &p, &sharded(shards));
        assert_eq!(
            reference.result.c.max_abs_diff(&rep.result.c),
            0.0,
            "{shards} shards: C must gather the same bits"
        );
        assert_eq!(
            reference.result.u.max_abs_diff(&rep.result.u),
            0.0,
            "{shards} shards: U solves the same W"
        );
        assert_eq!(reference.result.p_indices, rep.result.p_indices);
        assert_shard_meta(&rep.meta, shards);
    }
}

#[test]
fn sharded_fast_uniform_is_bit_identical_across_shard_counts() {
    let _g = shard_guard();
    let o = psd_oracle();
    let p = landmarks();
    let cfg = FastConfig::uniform(20);
    let reference = exec::fast(&o, &p, cfg, &streamed(), &mut Rng::new(41));
    for shards in SHARD_COUNTS {
        // A fresh rng with the reference seed: the sharded path must make
        // exactly the reference's draws (S is drawn once, up front).
        let rep = exec::fast(&o, &p, cfg, &sharded(shards), &mut Rng::new(41));
        assert_eq!(reference.result.c.max_abs_diff(&rep.result.c), 0.0, "{shards} shards");
        assert_eq!(reference.result.u.max_abs_diff(&rep.result.u), 0.0, "{shards} shards");
        assert_eq!(reference.result.p_indices, rep.result.p_indices);
        assert_shard_meta(&rep.meta, shards);
    }
}

#[test]
fn sharded_fast_leverage_matches_within_reduction_tolerance() {
    let _g = shard_guard();
    let o = psd_oracle();
    let p = landmarks();
    let cfg = FastConfig::leverage(20); // Gram basis: per-shard partial folds
    let reference = exec::fast(&o, &p, cfg, &streamed(), &mut Rng::new(17));
    let u_scale = 1.0 + reference.result.u.fro_norm();
    for shards in SHARD_COUNTS {
        let rep = exec::fast(&o, &p, cfg, &sharded(shards), &mut Rng::new(17));
        // The gathered C panel is untouched by the reduction regrouping.
        assert_eq!(reference.result.c.max_abs_diff(&rep.result.c), 0.0, "{shards} shards");
        // The merged Gram regroups sums by shard boundary: scores move by
        // ~1e-16, selections stay pinned by the seed, and U tracks to
        // reduction-reordering tolerance.
        assert_eq!(
            reference.result.p_indices, rep.result.p_indices,
            "{shards} shards: pinned seed must keep the same selection"
        );
        let diff = reference.result.u.max_abs_diff(&rep.result.u);
        assert!(
            diff <= 1e-12 * u_scale,
            "{shards} shards: |ΔU| = {diff:e} exceeds reduction tolerance"
        );
        assert_shard_meta(&rep.meta, shards);
    }
}

#[test]
fn sharded_cur_fast_is_bit_identical_across_shard_counts() {
    let _g = shard_guard();
    let mut rng = Rng::new(23);
    let a = Matrix::randn(N, 37, &mut rng);
    let col_idx = vec![0, 5, 12, 20, 29, 36];
    let row_idx = vec![2, 9, 21, 33, 48, 56];
    for cfg in [FastCurConfig::uniform(14, 14), FastCurConfig::leverage(14, 14)] {
        let reference =
            exec::cur_fast(&a, &col_idx, &row_idx, cfg, &streamed(), &mut Rng::new(31));
        for shards in SHARD_COUNTS {
            let rep = exec::cur_fast(
                &a,
                &col_idx,
                &row_idx,
                cfg,
                &sharded(shards),
                &mut Rng::new(31),
            );
            assert_eq!(reference.result.c.max_abs_diff(&rep.result.c), 0.0, "{shards} shards");
            assert_eq!(reference.result.u.max_abs_diff(&rep.result.u), 0.0, "{shards} shards");
            assert_eq!(reference.result.r.max_abs_diff(&rep.result.r), 0.0, "{shards} shards");
            assert_eq!(reference.result.entries_for_u, rep.result.entries_for_u);
            assert_shard_meta(&rep.meta, shards);
        }
    }
}

/// Nested sharding-aware policy plumbing: builders applied to the outer
/// `Sharded` must reach the inner per-worker policy the runs actually use.
#[test]
fn sharded_policy_builders_reach_the_workers() {
    let _g = shard_guard();
    let o = psd_oracle();
    let p = landmarks();
    let reference = exec::nystrom(&o, &p, &streamed());
    let rep = exec::nystrom(&o, &p, &sharded(3).with_tile_rows(4));
    assert_eq!(reference.result.u.max_abs_diff(&rep.result.u), 0.0);
    assert_shard_meta(&rep.meta, 3);
}

#[test]
fn transient_worker_death_reexecutes_the_shard_bit_identically() {
    let _g = shard_guard();
    let o = psd_oracle();
    let p = landmarks();
    let reference = exec::nystrom(&o, &p, &streamed());
    let plan = Arc::new(
        FaultPlan::none().fail(FaultPoint::ShardWorkerDeath, FaultSpec::transient(2)),
    );
    {
        let _armed = faults::arm(Arc::clone(&plan));
        let rep = exec::nystrom(&o, &p, &sharded(3));
        assert_eq!(
            reference.result.c.max_abs_diff(&rep.result.c),
            0.0,
            "a re-executed shard must reproduce the same bits"
        );
        assert_eq!(reference.result.u.max_abs_diff(&rep.result.u), 0.0);
        let stats = rep.meta.shard.expect("sharded run carries stats");
        assert_eq!(stats.reexecuted, 1, "the death must be visible in accounting");
        assert_eq!(stats.workers.len(), 3, "every shard still reports a worker");
    }
    assert_eq!(plan.injected(FaultPoint::ShardWorkerDeath), 1);
}

// ---------------------------------------------------------------------------
// Request coalescing: K same-oracle requests, one stream pass.
// ---------------------------------------------------------------------------

/// A [`KernelOracle`] whose tile production blocks until released — holds
/// the single worker busy so identical requests pile into the admission
/// queue and must coalesce on the next dispatch.
struct GateOracle {
    inner: Arc<dyn KernelOracle + Send + Sync>,
    open: Mutex<bool>,
    cv: Condvar,
}

impl GateOracle {
    fn new(inner: Arc<dyn KernelOracle + Send + Sync>) -> Self {
        GateOracle { inner, open: Mutex::new(false), cv: Condvar::new() }
    }

    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait_open(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

impl KernelOracle for GateOracle {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Matrix {
        self.wait_open();
        self.inner.block(rows, cols)
    }

    fn row_block(&self, r0: usize, r1: usize, cols: &[usize]) -> Matrix {
        self.wait_open();
        self.inner.row_block(r0, r1, cols)
    }

    fn full_rows(&self, r0: usize, r1: usize) -> Matrix {
        self.wait_open();
        self.inner.full_rows(r0, r1)
    }

    fn entries_observed(&self) -> u64 {
        self.inner.entries_observed()
    }

    fn reset_entries(&self) {
        self.inner.reset_entries();
    }
}

fn rbf() -> RbfOracle {
    let mut rng = Rng::new(3);
    RbfOracle::cpu(Arc::new(Matrix::randn(N, 6, &mut rng)), 0.5)
}

fn nystrom_req(id: u64, seed: u64) -> ApproxRequest {
    ApproxRequest {
        id,
        method: MethodSpec::Nystrom,
        c: 8,
        k: 3,
        seed,
        policy: None,
        precision: Precision::F64,
        deadline: None,
    }
}

/// Admission only defers work when the memory cap blocks it — uncapped
/// reservations always succeed, so without a cap every submit would
/// dispatch straight to the worker pool and the admission queue (where
/// riders are swept from) would stay empty. Capping at exactly one
/// request's predicted peak makes the gate deterministic: the blocker
/// holds the whole cap and every later submit queues.
fn gated_service() -> (Arc<GateOracle>, ApproxService) {
    let gate = Arc::new(GateOracle::new(Arc::new(rbf())));
    let cap = planner::predicted_policy_peak_bytes(
        N,
        8,
        &MethodSpec::Nystrom,
        &planner::default_policy(),
    );
    let svc = ApproxService::new(
        Arc::clone(&gate) as Arc<dyn KernelOracle + Send + Sync>,
        ServiceConfig { workers: 1, memory_cap: Some(cap), ..Default::default() },
    );
    (gate, svc)
}

#[test]
fn coalesced_requests_charge_the_oracle_exactly_one_pass() {
    const K: u64 = 4;
    // Entry cost of one Nyström build: n·c, independent of the seed (the
    // seed picks WHICH c columns are gathered, never how many entries).
    // Measured rather than assumed, on an identical but ungated oracle.
    let one_build = {
        let svc = ApproxService::new(
            Arc::new(rbf()) as Arc<dyn KernelOracle + Send + Sync>,
            ServiceConfig { workers: 1, ..Default::default() },
        );
        let (tx, rx) = mpsc::channel();
        svc.submit(nystrom_req(0, 7), tx);
        svc.drain();
        let r = rx.iter().next().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(!r.batched, "a singleton dispatch is not a shared pass");
        r.meta
            .expect("served requests carry meta")
            .entries
            .expect("oracle-backed runs count entries")
    };
    assert_eq!(one_build, (N * 8) as u64, "premise: Nyström reads exactly n·c");

    let (gate, svc) = gated_service();
    // The blocker (a DIFFERENT seed, so it can never coalesce with the
    // riders) takes the whole cap and parks on the closed gate...
    let (tx_b, rx_b) = mpsc::channel();
    svc.submit(nystrom_req(100, 99), tx_b);
    // ...so the K identical requests all land in the admission queue.
    let (tx, rx) = mpsc::channel();
    for id in 1..=K {
        svc.submit(nystrom_req(id, 7), tx.clone());
    }
    drop(tx);
    gate.release();
    svc.drain();

    let rb = rx_b.iter().next().unwrap();
    assert!(rb.error.is_none(), "{:?}", rb.error);
    assert!(!rb.batched, "the blocker dispatched alone");
    let mut resps: Vec<_> = rx.iter().collect();
    resps.sort_by_key(|r| r.id);
    assert_eq!(resps.len() as u64, K, "every rider must be replied to");
    for r in &resps {
        assert!(r.error.is_none(), "request {}: {:?}", r.id, r.error);
        assert!(r.batched, "request {} must see it rode a shared pass", r.id);
        assert_eq!(r.eigvals.len(), 3);
    }
    // All K riders returned the same build: identical spectra.
    for r in &resps[1..] {
        assert_eq!(r.eigvals, resps[0].eigvals, "riders share the leader's bits");
    }
    // The oracle's ledger: one blocker build + ONE batch build. Without
    // coalescing this would read (K + 1)·n·c.
    assert_eq!(
        gate.entries_observed(),
        2 * one_build,
        "K same-oracle requests must charge the oracle exactly one n·c"
    );
    let m = svc.metrics();
    assert_eq!(m.coalesced_requests.get(), K - 1, "riders counted, leader not");
    assert_eq!(m.completed.get(), K + 1, "every reply is a completion");
    assert_eq!(m.batch_occupancy.max(), K, "the shared dispatch carried all K");
    assert_eq!(m.batch_occupancy.count(), 2, "two dispatches: blocker + batch");
    assert_eq!(m.batch_occupancy.quantile(0.95), K, "p95 occupancy sees the batch");
    assert_eq!(m.mem_in_use.get(), 0, "riders never hold reservations");
}

/// Requests that differ in any identity field (here: the seed) must NOT
/// coalesce, even when they sit in the queue side by side.
#[test]
fn different_seed_requests_do_not_coalesce() {
    let (gate, svc) = gated_service();
    let (tx_b, rx_b) = mpsc::channel();
    svc.submit(nystrom_req(100, 99), tx_b);
    let (tx, rx) = mpsc::channel();
    for id in 1..=3u64 {
        svc.submit(nystrom_req(id, id), tx.clone()); // distinct seeds
    }
    drop(tx);
    gate.release();
    svc.drain();
    let _ = rx_b.iter().next().unwrap();
    let resps: Vec<_> = rx.iter().collect();
    assert_eq!(resps.len(), 3);
    for r in &resps {
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(!r.batched, "request {} must have run its own build", r.id);
    }
    assert_eq!(svc.metrics().coalesced_requests.get(), 0);
    // 4 builds: the blocker + one per distinct seed.
    assert_eq!(gate.entries_observed(), 4 * (N * 8) as u64);
}
