//! Bench: hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! GEMM v2 (packed/pooled), SYRK vs full gemm, fused-epilogue RBF blocks,
//! SVD/pinv, σ-calibration, and the PJRT path when artifacts exist.
//!
//! Emits machine-readable `BENCH_hotpath.json` (name, mean/p50/p95 secs,
//! GFLOP/s) so the perf trajectory is tracked across PRs; `make perf-check`
//! runs it in quick mode (`FASTSPSD_BENCH_QUICK=1`).

use fastspsd::benchkit::{black_box, BenchSuite};
use fastspsd::coordinator::engine::{
    rbf_cross_cpu, rbf_cross_cpu_f32, rbf_gram_cpu, KernelEngine,
};
use fastspsd::data::sigma;
use fastspsd::linalg::{gemm, pinv, svd_thin, Matrix};
use fastspsd::util::Rng;

fn main() {
    let mut rng = Rng::new(0);
    let mut suite = BenchSuite::new("hot paths");
    suite.header();
    println!("  ({} worker threads)", fastspsd::pool::configured_threads());

    // GEMM scaling (allocating wrapper — the historical headline numbers)
    for &n in &[128usize, 256, 512] {
        let a = Matrix::randn(n, n, &mut rng);
        let b = Matrix::randn(n, n, &mut rng);
        let flops = 2.0 * (n as f64).powi(3);
        suite.bench_flops(&format!("gemm {n}x{n}x{n}"), flops, || {
            black_box(a.matmul(&b));
        });
    }

    // gemm_into: same product, caller-provided output (no allocation)
    {
        let n = 512;
        let a = Matrix::randn(n, n, &mut rng);
        let b = Matrix::randn(n, n, &mut rng);
        let mut c = Matrix::zeros(n, n);
        let flops = 2.0 * (n as f64).powi(3);
        suite.bench_flops("gemm_into 512x512x512", flops, || {
            gemm::gemm_into(&a, &b, &mut c);
            black_box(c.data()[0]);
        });
    }

    // SYRK vs same-shape full product (acceptance: syrk >= 1.3x faster)
    {
        let a = Matrix::randn(512, 512, &mut rng);
        let flops = 2.0 * 512f64.powi(3);
        suite.bench_flops("gemm_nt(A,A) 512x512", flops, || {
            black_box(a.matmul_tr(&a));
        });
        // same nominal flop count, so the GFLOP/s column shows the saving
        suite.bench_flops("syrk_nt 512x512", flops, || {
            black_box(gemm::syrk_nt(&a));
        });
        if let (Some(full), Some(tri)) = (suite.mean_of("gemm_nt(A,A) 512x512"), suite.mean_of("syrk_nt 512x512")) {
            println!("    syrk speedup over gemm_nt: {:.2}x", full / tri);
        }
    }

    // Mixed-precision tile plane: the f32-stored panel kernels (f32 packs,
    // f64 accumulation) against their f64 twins at the same nominal flop
    // count, so the GFLOP/s column shows what the narrower packs/stores buy.
    {
        let a = Matrix::randn(512, 512, &mut rng);
        let b = Matrix::randn(512, 512, &mut rng);
        let flops = 2.0 * 512f64.powi(3);
        let id = |_: usize, _: usize, v: f64| v;
        suite.bench_flops("gemm_nt_map f64 512x512", flops, || {
            black_box(gemm::gemm_nt_map(&a, &b, &id));
        });
        suite.bench_flops("gemm_nt_map f32 512x512", flops, || {
            black_box(gemm::gemm_nt_map_f32(&a, &b, &id));
        });
        suite.bench_flops("syrk_nt_map f64 512x512", flops, || {
            black_box(gemm::syrk_nt_map(&a, &id));
        });
        suite.bench_flops("syrk_nt_map f32 512x512", flops, || {
            black_box(gemm::syrk_nt_map_f32(&a, &id));
        });
        if let (Some(wide), Some(narrow)) = (
            suite.mean_of("gemm_nt_map f64 512x512"),
            suite.mean_of("gemm_nt_map f32 512x512"),
        ) {
            println!("    f32 speedup over f64 gemm_nt_map: {:.2}x", wide / narrow);
        }
    }

    // factorizations at algorithm-relevant sizes
    let c128 = Matrix::randn(1024, 64, &mut rng);
    suite.bench("svd_thin 1024x64", || {
        black_box(svd_thin(&c128));
    });
    suite.bench("pinv 1024x64", || {
        black_box(pinv(&c128));
    });
    let sq = Matrix::randn(256, 256, &mut rng);
    suite.bench("svd_thin 256x256", || {
        black_box(svd_thin(&sq));
    });

    // RBF blocks: fused-epilogue cross + symmetric gram paths
    let x = Matrix::randn(512, 16, &mut rng);
    let y = Matrix::randn(512, 16, &mut rng);
    suite.bench("rbf_cross_cpu 512x512x16", || {
        black_box(rbf_cross_cpu(&x, &y, 0.5));
    });
    suite.bench("rbf_gram_cpu 512x512x16", || {
        black_box(rbf_gram_cpu(&x, 0.5));
    });
    // the oracle's f32 tile path: same fused epilogue, f32 tile out
    suite.bench("rbf_cross_cpu_f32 512x512x16", || {
        black_box(rbf_cross_cpu_f32(&x, &y, 0.5));
    });

    // σ-calibration: the bisection loop re-exponentiates one precomputed
    // distance matrix instead of rebuilding ~40 kernels
    let blob = Matrix::randn(300, 8, &mut rng);
    suite.bench("calibrate_sigma n=300", || {
        black_box(sigma::calibrate_sigma(&blob, 0.9, 300, 7));
    });

    // PJRT path (if artifacts available)
    let engine = KernelEngine::auto();
    if engine.is_pjrt() {
        suite.bench("rbf_cross_pjrt 512x512x16", || {
            black_box(engine.rbf_cross(&x, &y, 0.5));
        });
        let x1024 = Matrix::randn(1024, 128, &mut rng);
        suite.bench("rbf_cross_pjrt 1024x1024x128", || {
            black_box(engine.rbf_cross(&x1024, &x1024, 0.5));
        });
        suite.bench("rbf_cross_cpu  1024x1024x128", || {
            black_box(rbf_cross_cpu(&x1024, &x1024, 0.5));
        });
    } else {
        println!("  (PJRT engine unavailable — run `make artifacts` to bench the AOT path)");
    }

    // Quick smoke runs land in a separate file so they never clobber the
    // full-budget perf trajectory — unless commit mode (`make bench-quick`)
    // asks for the canonical artifact.
    let path = fastspsd::benchkit::artifact_path("BENCH_hotpath");
    if let Err(e) = suite.write_json(&path) {
        eprintln!("warn: could not write {path}: {e}");
    }
}
