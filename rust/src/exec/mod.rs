//! One execution-policy API for every algorithm family.
//!
//! Gittens & Mahoney style sketch-and-solve makes Nyström, the prototype
//! model, the fast model, fast CUR, and the implicit operators instances
//! of one template; what used to distinguish `nystrom` /
//! `nystrom_streamed` / `nystrom_resident` (and the `_budgeted` /
//! `_resident` implicit ops) was never the algorithm — only the
//! *execution policy*. This module is that policy surface:
//!
//! - [`ExecPolicy`] picks the traversal: [`Materialized`]
//!   (whole-matrix tiles), [`Streamed`] (the bounded tile pipeline),
//!   [`Resident`] (the pipeline behind the hot-tile LRU + disk-spill
//!   residency layer), or [`Sharded`] (row-sharded workers running an
//!   inner policy over their own row-blocks, partial states merged by
//!   the [`shard`](crate::shard) coordinator).
//! - one public entry per algorithm family — [`nystrom`], [`prototype`],
//!   [`fast`], [`cur_fast`], [`top_k_eigs`], [`solve_regularized`] — each
//!   `(source-or-oracle, algo-config, &ExecPolicy, rng) → RunReport`.
//! - [`RunReport`] carries the result plus uniform accounting
//!   ([`RunMeta`]): source entries observed, compute seconds, residency
//!   counters, and predicted-vs-actual peak bytes.
//!
//! Policy changes never change *what* is computed: selection/gather paths
//! are bit-identical across every policy, reduction-regrouped paths
//! (prototype, projection sketches) agree to ≤1e-12 relative error
//! (`tests/exec_api.rs` asserts the full method × policy matrix). The old
//! suffixed entry points in [`spsd`](crate::spsd), [`cur`](crate::cur)
//! and [`stream::implicit`](crate::stream::implicit) remain as deprecated
//! shims over this module.
//!
//! A GPU/PJRT tile backend (ROADMAP) lands here as one more [`ExecPolicy`]
//! variant — no per-algorithm suffix required.
//!
//! [`Materialized`]: ExecPolicy::Materialized
//! [`Streamed`]: ExecPolicy::Streamed
//! [`Resident`]: ExecPolicy::Resident
//! [`Sharded`]: ExecPolicy::Sharded

pub mod policy;

pub use policy::{DegradeAction, DegradeInfo, ExecPolicy, RunMeta, RunReport};

use crate::benchkit::alloc::{self, AllocGauge};
use crate::coordinator::oracle::KernelOracle;
use crate::coordinator::planner::{self, MethodSpec};
use crate::cur::{self, CurDecomp, FastCurConfig};
use crate::linalg::{guard, Matrix};
use crate::obs::{self, Stage, StageProfile};
use crate::shard;
use crate::sketch::SketchKind;
use crate::spsd::{self, FastConfig, LeverageBasis, SpsdApprox};
use crate::stream::{self, TileSource};
use crate::util::{Rng, Stopwatch};

/// Wall clock + (optional) allocation gauge + span trace for one run.
///
/// With the recorder installed the scope opens an `exec.run` umbrella
/// span, and either borrows the caller's trace (the service path — the
/// profile is then a snapshot, the service drains at reply time) or mints
/// its own (bare `exec` calls — the profile drains, leaving nothing in
/// the central store).
struct Scope {
    sw: Stopwatch,
    gauge: AllocGauge,
    /// Raw trace id this run records under (0 = recorder off).
    trace: u64,
    /// True when the scope minted the trace itself and owns draining it.
    owned: bool,
    /// Keeps a minted trace current for the run's duration.
    tscope: Option<obs::TraceScope>,
    /// The `exec.run` umbrella span, closed in `finish`.
    span: Option<obs::SpanGuard>,
}

impl Scope {
    fn start() -> Self {
        let (trace, owned, tscope) = if obs::installed() {
            let cur = obs::current_trace_raw();
            if cur == 0 {
                let t = obs::TraceId::mint().raw();
                (t, true, Some(obs::trace_scope(t)))
            } else {
                (cur, false, None)
            }
        } else {
            (0, false, None)
        };
        // Open the umbrella only after the trace tag is in place.
        let span = (trace != 0).then(|| obs::span(Stage::ExecRun));
        // Discard numeric-health residue left on this thread by earlier
        // unrelated work, so the run's record starts clean.
        let _ = guard::take_health();
        Scope { sw: Stopwatch::start(), gauge: AllocGauge::start(), trace, owned, tscope, span }
    }

    fn finish(
        mut self,
        entries: Option<u64>,
        residency: Option<stream::ResidencyStats>,
        predicted_peak_bytes: Option<u64>,
        precision: stream::Precision,
    ) -> RunMeta {
        let actual = alloc::installed().then(|| self.gauge.peak_extra_bytes() as u64);
        let compute_secs = self.sw.secs();
        // Close the umbrella before collecting, so exec.run itself is in
        // the profile; then release the trace tag.
        drop(self.span.take());
        drop(self.tscope.take());
        let stage_profile = (self.trace != 0).then(|| {
            let records = if self.owned {
                obs::drain_trace(self.trace)
            } else {
                obs::snapshot_trace(self.trace)
            };
            StageProfile::from_records(&records, obs::current_thread_id())
        });
        // Drain the thread-local numeric-health record (guarded core
        // solves + quarantine notes all ran on this thread) and fold in
        // the residency layer's corrupt-read counter.
        let mut numeric_health = guard::take_health();
        if let Some(rs) = &residency {
            numeric_health.corrupt_reads = rs.corrupt_reads;
        }
        RunMeta {
            entries,
            compute_secs,
            residency,
            predicted_peak_bytes,
            actual_peak_bytes: actual,
            degraded: None,
            precision,
            stage_profile,
            numeric_health,
            shard: None,
        }
    }
}

/// The Nyström method (`U = W†`, paper eq. 3) under `policy`.
/// Bit-identical results across every policy (pure gathers).
pub fn nystrom(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    policy: &ExecPolicy,
) -> RunReport<SpsdApprox> {
    let scope = Scope::start();
    let n = oracle.n();
    if let ExecPolicy::Sharded { shards, inner } = policy {
        let rc = inner.residency_config();
        let (approx, stats, shard_stats) =
            shard::nystrom_sharded(oracle, p_idx, *shards, inner.stream_config(), rc.as_ref());
        let predicted =
            planner::predicted_policy_peak_bytes(n, p_idx.len(), &MethodSpec::Nystrom, policy);
        let entries = Some(approx.entries_observed);
        let mut meta = scope.finish(entries, stats, Some(predicted), policy.precision());
        meta.shard = Some(shard_stats);
        return RunReport { result: approx, meta };
    }
    let rc = policy.residency_config();
    let (approx, stats) =
        spsd::run_nystrom(oracle, p_idx, policy.stream_config(), rc.as_ref());
    let predicted =
        planner::predicted_policy_peak_bytes(n, p_idx.len(), &MethodSpec::Nystrom, policy);
    let entries = Some(approx.entries_observed);
    RunReport { result: approx, meta: scope.finish(entries, stats, Some(predicted), policy.precision()) }
}

/// The prototype model (`U* = C† K (C†)ᵀ`, paper eq. 2) under `policy`.
///
/// The prototype streams the full `K` — not a reloadable working set — so
/// a [`Resident`](ExecPolicy::Resident) policy degrades to the streamed
/// pipeline at the policy's tile height (`residency` stays `None` in the
/// report). Streamed results match materialized ones up to reduction
/// reordering (≤1e-12 relative).
pub fn prototype(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    policy: &ExecPolicy,
) -> RunReport<SpsdApprox> {
    if let ExecPolicy::Sharded { inner, .. } = policy {
        // The prototype streams the full `K` with a fold whose scratch is
        // `O(tile·n)` — not a row-shardable working set here; serve it
        // with the per-worker policy instead (meta.shard stays None).
        return prototype(oracle, p_idx, inner);
    }
    let scope = Scope::start();
    let n = oracle.n();
    let approx = spsd::run_prototype(oracle, p_idx, policy.stream_config());
    let predicted =
        planner::predicted_policy_peak_bytes(n, p_idx.len(), &MethodSpec::Prototype, policy);
    let entries = Some(approx.entries_observed);
    RunReport { result: approx, meta: scope.finish(entries, None, Some(predicted), policy.precision()) }
}

/// The fast SPSD model (paper Algorithm 1) under `policy`.
///
/// Selection sketches (uniform / leverage) are bit-identical across every
/// policy; projection sketches regroup reductions when tiled (≤1e-12) and
/// — like the prototype — stream the full `K`, so for them a
/// [`Resident`](ExecPolicy::Resident) policy degrades to plain streaming
/// (`residency` stays `None` in the report).
pub fn fast(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    cfg: FastConfig,
    policy: &ExecPolicy,
    rng: &mut Rng,
) -> RunReport<SpsdApprox> {
    if let ExecPolicy::Sharded { shards, inner } = policy {
        // Row-shardable: uniform selection (S drawn up front) and the
        // streamed leverage estimators (associative score partials).
        // Projection sketches and the ExactSvd leverage reference need
        // state no worker can fold locally — serve those with the
        // per-worker policy (meta.shard stays None).
        let shardable = match cfg.kind {
            SketchKind::Uniform => true,
            SketchKind::Leverage { .. } => {
                !matches!(cfg.leverage_basis, LeverageBasis::ExactSvd)
            }
            _ => false,
        };
        if !shardable {
            return fast(oracle, p_idx, cfg, inner, rng);
        }
        let scope = Scope::start();
        let n = oracle.n();
        let rc = inner.residency_config();
        let (approx, stats, shard_stats) = shard::fast_sharded(
            oracle,
            p_idx,
            cfg,
            *shards,
            inner.stream_config(),
            rc.as_ref(),
            rng,
        );
        let method = MethodSpec::Fast { s: cfg.s, kind: cfg.kind };
        let predicted = planner::predicted_policy_peak_bytes(n, p_idx.len(), &method, policy);
        let entries = Some(approx.entries_observed);
        let mut meta = scope.finish(entries, stats, Some(predicted), policy.precision());
        meta.shard = Some(shard_stats);
        return RunReport { result: approx, meta };
    }
    let scope = Scope::start();
    let n = oracle.n();
    let rc = if cfg.kind.is_column_selection() { policy.residency_config() } else { None };
    let (approx, stats) =
        spsd::run_fast(oracle, p_idx, cfg, policy.stream_config(), rc.as_ref(), rng);
    let method = MethodSpec::Fast { s: cfg.s, kind: cfg.kind };
    let predicted = planner::predicted_policy_peak_bytes(n, p_idx.len(), &method, policy);
    let entries = Some(approx.entries_observed);
    RunReport { result: approx, meta: scope.finish(entries, stats, Some(predicted), policy.precision()) }
}

/// Fast CUR (`Ũ = (S_Cᵀ C)† (S_Cᵀ A S_R) (R S_R)†`, paper eq. 9) under
/// `policy`. Bit-identical across every policy (pure gathers);
/// `meta.entries` reports the decomposition's `entries_for_u` (the entries
/// read to compute `U` — `C`/`R` are shared by every method). No peak
/// prediction exists for rectangular `A` (`predicted_peak_bytes` is
/// `None`); the service's square-kernel CUR is predicted by
/// [`planner::predicted_policy_peak_bytes`].
pub fn cur_fast(
    a: &Matrix,
    col_idx: &[usize],
    row_idx: &[usize],
    cfg: FastCurConfig,
    policy: &ExecPolicy,
    rng: &mut Rng,
) -> RunReport<CurDecomp> {
    let scope = Scope::start();
    if let ExecPolicy::Sharded { shards, inner } = policy {
        let rc = inner.residency_config();
        let (decomp, stats, shard_stats) = shard::cur_fast_sharded(
            a,
            col_idx,
            row_idx,
            cfg,
            *shards,
            inner.stream_config(),
            rc.as_ref(),
            rng,
        );
        let entries = Some(decomp.entries_for_u);
        let mut meta = scope.finish(entries, stats, None, policy.precision());
        meta.shard = Some(shard_stats);
        return RunReport { result: decomp, meta };
    }
    let stream_cfg = match policy {
        ExecPolicy::Materialized => None,
        _ => Some(policy.stream_config()),
    };
    let rc = policy.residency_config();
    let (decomp, stats) =
        cur::run_cur_fast(a, col_idx, row_idx, cfg, stream_cfg, rc.as_ref(), rng);
    let entries = Some(decomp.entries_for_u);
    RunReport { result: decomp, meta: scope.finish(entries, stats, None, policy.precision()) }
}

/// Top-k eigenpairs (descending) of the implicit `C U Cᵀ` via Lanczos
/// over the streamed matvec, under `policy`. A
/// [`Resident`](ExecPolicy::Resident) policy charges the underlying
/// source exactly once per tile across all Lanczos iterations (with
/// `spill`, at any RAM budget including 0); results are bit-identical
/// across every policy.
pub fn top_k_eigs(
    src: &dyn TileSource,
    u: &Matrix,
    k: usize,
    seed: u64,
    policy: &ExecPolicy,
) -> RunReport<(Vec<f64>, Matrix)> {
    if let ExecPolicy::Sharded { inner, .. } = policy {
        // Lanczos is an iteration of full-source matvecs; sharding one
        // matvec buys nothing over the pipeline's own tiling. Serve with
        // the per-worker policy (meta.shard stays None).
        return top_k_eigs(src, u, k, seed, inner);
    }
    let scope = Scope::start();
    let cfg = policy.stream_config();
    let rc = policy.residency_config();
    let (result, stats) = stream::implicit::run_top_k_eigs(src, u, k, seed, cfg, rc.as_ref());
    let predicted = implicit_predicted(src, cfg, policy);
    RunReport { result, meta: scope.finish(None, stats, Some(predicted), policy.precision()) }
}

/// Solve `(C U Cᵀ + alpha I) w = y` against the implicit approximation
/// (streamed Woodbury, paper Lemma 11) under `policy`. Same policy
/// semantics as [`top_k_eigs`].
pub fn solve_regularized(
    src: &dyn TileSource,
    u: &Matrix,
    alpha: f64,
    y: &[f64],
    policy: &ExecPolicy,
) -> RunReport<Vec<f64>> {
    if let ExecPolicy::Sharded { inner, .. } = policy {
        return solve_regularized(src, u, alpha, y, inner);
    }
    let scope = Scope::start();
    let cfg = policy.stream_config();
    let rc = policy.residency_config();
    let (result, stats) =
        stream::implicit::run_solve_regularized(src, u, alpha, y, cfg, rc.as_ref());
    let predicted = implicit_predicted(src, cfg, policy);
    RunReport { result, meta: scope.finish(None, stats, Some(predicted), policy.precision()) }
}

fn implicit_predicted(
    src: &dyn TileSource,
    cfg: stream::StreamConfig,
    policy: &ExecPolicy,
) -> u64 {
    let n = src.rows();
    planner::predicted_implicit_peak_bytes_prec(
        n,
        src.cols(),
        cfg.effective_tile_rows(n),
        policy.cache_budget(),
        policy.precision(),
    )
}
