//! CUR matrix decomposition (paper §5): `A ≈ C U R` with
//!
//! - [`cur_optimal`] — `U* = C† A R†` (eq. 8, cost O(mn·min{c,r})),
//! - [`cur_drineas08`] — `U = (P_R^T A P_C)†` (the cheap 2008 baseline the
//!   paper's Fig. 2(c) shows is poor),
//! - [`cur_fast`] — `Ũ = (S_C^T C)† (S_C^T A S_R) (R S_R)†` (eq. 9,
//!   Theorem 9) with uniform or leverage-score `S_C`, `S_R`,
//! - [`adaptive_sample`] / [`uniform_adaptive2`] — residual-based column
//!   selection (Wang et al. 2016) used to build better `C` (paper Fig. 4
//!   and Theorem 8's near-optimal selection).

pub mod sparse_cur;

use crate::linalg::{pinv, Matrix};
use crate::sketch::{self, SketchKind};
use crate::stream::{
    run_pipeline, ColSubsetCollect, MatrixSource, ResidencyConfig, ResidencyStats,
    ResidentSource, RowGather, StreamConfig,
};
use crate::util::{Rng, Stopwatch};

/// A CUR decomposition `A ≈ C U R`.
#[derive(Debug, Clone)]
pub struct CurDecomp {
    pub c: Matrix, // m x c
    pub u: Matrix, // c x r
    pub r: Matrix, // r x n
    pub method: String,
    pub build_secs: f64,
    /// Entries of `A` read to *compute U* (C and R excluded — all methods
    /// share them).
    pub entries_for_u: u64,
}

impl CurDecomp {
    pub fn materialize(&self) -> Matrix {
        self.c.matmul(&self.u).matmul(&self.r)
    }

    pub fn rel_fro_error(&self, a: &Matrix) -> f64 {
        a.sub(&self.materialize()).fro_norm_sq() / a.fro_norm_sq()
    }
}

/// Uniformly sample `count` distinct indices from `[0, n)`, sorted.
pub fn select_uniform(n: usize, count: usize, rng: &mut Rng) -> Vec<usize> {
    let mut idx = rng.sample_without_replacement(n, count.min(n));
    idx.sort_unstable();
    idx
}

/// Optimal U: `U* = C† A R†` — O(mn·min{c,r}).
pub fn cur_optimal(a: &Matrix, col_idx: &[usize], row_idx: &[usize]) -> CurDecomp {
    let sw = Stopwatch::start();
    let c = a.select_cols(col_idx);
    let r = a.select_rows(row_idx);
    let cp = pinv(&c); // c x m
    let rp = pinv(&r); // n x r
    let u = cp.matmul(a).matmul(&rp);
    CurDecomp {
        c,
        u,
        r,
        method: "optimal".into(),
        build_secs: sw.secs(),
        entries_for_u: (a.rows() * a.cols()) as u64,
    }
}

/// Drineas et al. (2008): `U = (P_R^T A P_C)† = (A[rows, cols])†` — the
/// degenerate fast model with `S_C = P_R`, `S_R = P_C`.
pub fn cur_drineas08(a: &Matrix, col_idx: &[usize], row_idx: &[usize]) -> CurDecomp {
    let sw = Stopwatch::start();
    let c = a.select_cols(col_idx);
    let r = a.select_rows(row_idx);
    let w = a.select_rows(row_idx).select_cols(col_idx); // r x c
    let u = pinv(&w); // c x r
    CurDecomp {
        c,
        u,
        r,
        method: "drineas08".into(),
        build_secs: sw.secs(),
        entries_for_u: (row_idx.len() * col_idx.len()) as u64,
    }
}

/// How CUR's leverage configs compute the scores of the sampling basis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CurScoreBasis {
    /// `O(c²)` Gram-based scores (the streamed leverage estimator —
    /// default). Squares the basis's condition number: directions with
    /// relative singular value between `√ε` and `ε` score at the Gram's
    /// rounding floor.
    Gram,
    /// SVD of the resident basis (the historical behavior): `O(m·c)`
    /// scratch, robust to ill-conditioned `C`/`R`.
    ExactSvd,
}

/// Configuration for the fast CUR U matrix (eq. 9).
#[derive(Debug, Clone, Copy)]
pub struct FastCurConfig {
    pub s_c: usize,
    pub s_r: usize,
    /// Uniform or Leverage (w.r.t. row leverage of C / column leverage of R).
    pub kind: SketchKind,
    /// Force the selected rows to include `row_idx` and columns to include
    /// `col_idx` (the CUR analogue of Corollary 5; improves accuracy).
    pub force_overlap: bool,
    /// Score estimator for `SketchKind::Leverage` (ignored otherwise).
    pub score_basis: CurScoreBasis,
}

impl FastCurConfig {
    pub fn uniform(s_c: usize, s_r: usize) -> Self {
        FastCurConfig {
            s_c,
            s_r,
            kind: SketchKind::Uniform,
            force_overlap: true,
            score_basis: CurScoreBasis::Gram,
        }
    }

    pub fn leverage(s_c: usize, s_r: usize) -> Self {
        FastCurConfig {
            s_c,
            s_r,
            kind: SketchKind::Leverage { scaled: false },
            force_overlap: true,
            score_basis: CurScoreBasis::Gram,
        }
    }

    /// Leverage with SVD-based scores (the conditioning-robust reference).
    pub fn leverage_svd(s_c: usize, s_r: usize) -> Self {
        FastCurConfig { score_basis: CurScoreBasis::ExactSvd, ..Self::leverage(s_c, s_r) }
    }
}

/// Fast CUR: `Ũ = (S_C^T C)† (S_C^T A S_R) (R S_R)†`, column-selection
/// sketches only (the linear-time regime the paper recommends; projection
/// sketches would need all of A).
pub fn cur_fast(
    a: &Matrix,
    col_idx: &[usize],
    row_idx: &[usize],
    cfg: FastCurConfig,
    rng: &mut Rng,
) -> CurDecomp {
    let sw = Stopwatch::start();
    let (m, n) = (a.rows(), a.cols());
    let c = a.select_cols(col_idx);
    let r = a.select_rows(row_idx);

    // Row sketch S_C over [m] (samples rows), column sketch S_R over [n].
    let sc_idx = build_indices(&c, cfg.kind, cfg.score_basis, cfg.s_c, m, if cfg.force_overlap { row_idx } else { &[] }, rng);
    let rt = r.transpose();
    let sr_idx = build_indices(&rt, cfg.kind, cfg.score_basis, cfg.s_r, n, if cfg.force_overlap { col_idx } else { &[] }, rng);

    let stc = c.select_rows(&sc_idx); // s_c x c
    let rsr = r.select_cols(&sr_idx); // r x s_r
    let core = a.select_rows(&sc_idx).select_cols(&sr_idx); // s_c x s_r
    let u = pinv(&stc).matmul(&core).matmul(&pinv(&rsr));
    CurDecomp {
        c,
        u,
        r,
        method: format!("fast[{}]", cfg.kind.name()),
        build_secs: sw.secs(),
        entries_for_u: (sc_idx.len() * sr_idx.len()) as u64,
    }
}

/// Fast CUR through the tile pipeline: `A` flows by in `tile_rows`-high
/// row tiles and the consumers pick out everything the decomposition
/// needs — `C = A[:, P_C]` (column-subset collect), `R = A[P_R, :]` (row
/// gather), and for uniform sketches the `S_C x S_R` core in the same
/// single pass (the indices don't depend on `C`/`R`, so they are drawn up
/// front with the same rng sequence as [`cur_fast`] — results are
/// bit-identical). Leverage sketches need `C`/`R` first, so they pay a
/// second column-restricted pass for the core. Peak extra memory beyond
/// the `C`/`R`/`U` outputs is `O(tile_rows · n + s_c · s_r)` — the tile
/// interface is what a dataset-on-disk source would implement.
pub fn cur_fast_streamed(
    a: &Matrix,
    col_idx: &[usize],
    row_idx: &[usize],
    cfg: FastCurConfig,
    stream_cfg: StreamConfig,
    rng: &mut Rng,
) -> CurDecomp {
    let sw = Stopwatch::start();
    let (m, n) = (a.rows(), a.cols());
    let forced_rows: &[usize] = if cfg.force_overlap { row_idx } else { &[] };
    let forced_cols: &[usize] = if cfg.force_overlap { col_idx } else { &[] };

    let (c, r, sc_idx, sr_idx, core) = match cfg.kind {
        SketchKind::Uniform => {
            // Indices first (basis is ignored for uniform sampling), then
            // one pass gathers C, R and the core together.
            let dummy = Matrix::zeros(0, 0);
            let sc_idx = build_indices(&dummy, cfg.kind, cfg.score_basis, cfg.s_c, m, forced_rows, rng);
            let sr_idx = build_indices(&dummy, cfg.kind, cfg.score_basis, cfg.s_r, n, forced_cols, rng);
            let src = MatrixSource::new(a);
            let mut c_collect = ColSubsetCollect::new(m, col_idx.to_vec());
            let mut r_gather = RowGather::new(row_idx.to_vec(), n);
            let mut core_gather = RowGather::with_cols(sc_idx.clone(), sr_idx.clone());
            run_pipeline(
                &src,
                stream_cfg.tile_rows,
                stream_cfg.queue_depth,
                &mut [&mut c_collect, &mut r_gather, &mut core_gather],
            );
            (
                c_collect.into_matrix(),
                r_gather.into_matrix(),
                sc_idx,
                sr_idx,
                core_gather.into_matrix(),
            )
        }
        SketchKind::Leverage { .. } => {
            // Pass 1: C and R. Then draw the leverage indices exactly as
            // cur_fast does; the s_c x s_r core is a direct gather from
            // the resident `a` (it cannot be folded in pass 1 — the
            // indices don't exist yet — and re-streaming all m rows to
            // keep s_c of them would be pure overhead).
            let src = MatrixSource::new(a);
            let mut c_collect = ColSubsetCollect::new(m, col_idx.to_vec());
            let mut r_gather = RowGather::new(row_idx.to_vec(), n);
            run_pipeline(
                &src,
                stream_cfg.tile_rows,
                stream_cfg.queue_depth,
                &mut [&mut c_collect, &mut r_gather],
            );
            let c = c_collect.into_matrix();
            let r = r_gather.into_matrix();
            let sc_idx = build_indices(&c, cfg.kind, cfg.score_basis, cfg.s_c, m, forced_rows, rng);
            let rt = r.transpose();
            let sr_idx = build_indices(&rt, cfg.kind, cfg.score_basis, cfg.s_r, n, forced_cols, rng);
            let core =
                Matrix::from_fn(sc_idx.len(), sr_idx.len(), |i, j| a[(sc_idx[i], sr_idx[j])]);
            (c, r, sc_idx, sr_idx, core)
        }
        other => panic!("fast CUR supports column-selection sketches, not {}", other.name()),
    };

    let stc = c.select_rows(&sc_idx); // s_c x c
    let rsr = r.select_cols(&sr_idx); // r x s_r
    let u = pinv(&stc).matmul(&core).matmul(&pinv(&rsr));
    CurDecomp {
        c,
        u,
        r,
        method: format!("fast[{}]", cfg.kind.name()),
        build_secs: sw.secs(),
        entries_for_u: (sc_idx.len() * sr_idx.len()) as u64,
    }
}

/// [`cur_fast_streamed`] through the tile residency layer: `A`'s row
/// tiles write through an LRU + disk spill arena on first read, and the
/// leverage family's **pass 2** (the `S_C x S_R` core gather, which
/// cannot run in pass 1 because the indices don't exist yet) re-streams
/// through the residency layer instead of indexing the resident matrix —
/// so a disk-backed `A` (the stand-in [`MatrixSource`] models) is read
/// exactly once however many passes run. Results are bit-identical to
/// [`cur_fast`] / [`cur_fast_streamed`] (same rng sequence, exact
/// gathers); returns the residency counters alongside the decomposition.
pub fn cur_fast_streamed_resident(
    a: &Matrix,
    col_idx: &[usize],
    row_idx: &[usize],
    cfg: FastCurConfig,
    stream_cfg: StreamConfig,
    residency: &ResidencyConfig,
    rng: &mut Rng,
) -> (CurDecomp, ResidencyStats) {
    let sw = Stopwatch::start();
    let (m, n) = (a.rows(), a.cols());
    let forced_rows: &[usize] = if cfg.force_overlap { row_idx } else { &[] };
    let forced_cols: &[usize] = if cfg.force_overlap { col_idx } else { &[] };
    let src = MatrixSource::new(a);
    let resident = ResidentSource::new(&src, residency);
    let t = stream_cfg.effective_tile_rows(m);

    let (c, r, sc_idx, sr_idx, core) = match cfg.kind {
        SketchKind::Uniform => {
            let dummy = Matrix::zeros(0, 0);
            let sc_idx = build_indices(&dummy, cfg.kind, cfg.score_basis, cfg.s_c, m, forced_rows, rng);
            let sr_idx = build_indices(&dummy, cfg.kind, cfg.score_basis, cfg.s_r, n, forced_cols, rng);
            let mut c_collect = ColSubsetCollect::new(m, col_idx.to_vec());
            let mut r_gather = RowGather::new(row_idx.to_vec(), n);
            let mut core_gather = RowGather::with_cols(sc_idx.clone(), sr_idx.clone());
            run_pipeline(
                &resident,
                t,
                stream_cfg.queue_depth,
                &mut [&mut c_collect, &mut r_gather, &mut core_gather],
            );
            (
                c_collect.into_matrix(),
                r_gather.into_matrix(),
                sc_idx,
                sr_idx,
                core_gather.into_matrix(),
            )
        }
        SketchKind::Leverage { .. } => {
            // Pass 1: C and R; every tile writes through the arena.
            let mut c_collect = ColSubsetCollect::new(m, col_idx.to_vec());
            let mut r_gather = RowGather::new(row_idx.to_vec(), n);
            run_pipeline(
                &resident,
                t,
                stream_cfg.queue_depth,
                &mut [&mut c_collect, &mut r_gather],
            );
            let c = c_collect.into_matrix();
            let r = r_gather.into_matrix();
            let sc_idx = build_indices(&c, cfg.kind, cfg.score_basis, cfg.s_c, m, forced_rows, rng);
            let rt = r.transpose();
            let sr_idx = build_indices(&rt, cfg.kind, cfg.score_basis, cfg.s_r, n, forced_cols, rng);
            // Pass 2: the core gather reloads tiles from residency — the
            // backing store is never consulted again.
            let mut core_gather = RowGather::with_cols(sc_idx.clone(), sr_idx.clone());
            run_pipeline(&resident, t, stream_cfg.queue_depth, &mut [&mut core_gather]);
            (c, r, sc_idx, sr_idx, core_gather.into_matrix())
        }
        other => panic!("fast CUR supports column-selection sketches, not {}", other.name()),
    };

    let stc = c.select_rows(&sc_idx);
    let rsr = r.select_cols(&sr_idx);
    let u = pinv(&stc).matmul(&core).matmul(&pinv(&rsr));
    let decomp = CurDecomp {
        c,
        u,
        r,
        method: format!("fast[{}]", cfg.kind.name()),
        build_secs: sw.secs(),
        entries_for_u: (sc_idx.len() * sr_idx.len()) as u64,
    };
    (decomp, resident.stats())
}

/// Sample `s` row indices of `basis` (uniform or by row leverage scores),
/// unioned with `forced`.
fn build_indices(
    basis: &Matrix,
    kind: SketchKind,
    score_basis: CurScoreBasis,
    s: usize,
    n: usize,
    forced: &[usize],
    rng: &mut Rng,
) -> Vec<usize> {
    let extra = s.saturating_sub(forced.len()).max(1);
    let mut idx: Vec<usize> = match kind {
        SketchKind::Uniform => rng.sample_without_replacement(n, extra.min(n)),
        SketchKind::Leverage { .. } => {
            // Default: Gram-based scores (the streamed leverage
            // estimator) — O(c²) whitening state instead of an SVD of the
            // full basis, same scores in exact arithmetic, and shared by
            // `cur_fast` and `cur_fast_streamed` so the two stay
            // bit-identical. ExactSvd is the conditioning-robust opt-out.
            let scores = match score_basis {
                CurScoreBasis::Gram => {
                    sketch::approx_leverage_from_gram(&basis.gram_tn()).scores(basis)
                }
                CurScoreBasis::ExactSvd => sketch::leverage_scores(basis),
            };
            let rank: f64 = scores.iter().sum();
            let mut out = Vec::new();
            for (i, &l) in scores.iter().enumerate() {
                let p = if rank > 0.0 { (extra as f64 * l / rank).min(1.0) } else { extra as f64 / n as f64 };
                if rng.bernoulli(p) {
                    out.push(i);
                }
            }
            if out.is_empty() {
                out.push(rng.usize_below(n));
            }
            out
        }
        other => panic!("fast CUR supports column-selection sketches, not {}", other.name()),
    };
    idx.extend_from_slice(forced);
    idx.sort_unstable();
    idx.dedup();
    idx
}

/// Adaptive sampling (Wang & Zhang 2013): sample `count` extra column
/// indices with probability proportional to the squared column norms of the
/// residual `A - C C† A`. Requires the full matrix.
pub fn adaptive_sample(a: &Matrix, current_cols: &[usize], count: usize, rng: &mut Rng) -> Vec<usize> {
    let c = a.select_cols(current_cols);
    let cp = pinv(&c);
    let proj = c.matmul(&cp.matmul(a)); // C C† A
    // Residual column norms accumulated row-major in one streaming pass
    // (no column-strided reads, no residual matrix materialized).
    let mut weights = vec![0.0f64; a.cols()];
    for i in 0..a.rows() {
        let (ar, pr) = (a.row(i), proj.row(i));
        for (w, (&av, &pv)) in weights.iter_mut().zip(ar.iter().zip(pr)) {
            let r = av - pv;
            *w += r * r;
        }
    }
    let mut chosen = Vec::with_capacity(count);
    let mut w = weights;
    for &cidx in current_cols {
        w[cidx] = 0.0; // don't re-pick existing columns
    }
    for _ in 0..count {
        let j = rng.weighted_index(&w);
        chosen.push(j);
        w[j] = 0.0;
    }
    chosen.sort_unstable();
    chosen.dedup();
    chosen
}

/// The uniform+adaptive² column-selection of Wang et al. (2016): c/3
/// uniform, then two adaptive rounds of c/3 against the growing residual.
pub fn uniform_adaptive2(a: &Matrix, c: usize, rng: &mut Rng) -> Vec<usize> {
    let n = a.cols();
    let c1 = (c / 3).max(1);
    let c3 = c.saturating_sub(2 * c1).max(1);
    let mut idx = select_uniform(n, c1, rng);
    let extra1 = adaptive_sample(a, &idx, c1, rng);
    idx.extend(extra1);
    idx.sort_unstable();
    idx.dedup();
    let extra2 = adaptive_sample(a, &idx, c3, rng);
    idx.extend(extra2);
    idx.sort_unstable();
    idx.dedup();
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::gen;

    fn decaying_matrix(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let r = m.min(n);
        let u = crate::linalg::qr::qr_thin(&Matrix::randn(m, r, &mut rng)).q;
        let v = crate::linalg::qr::qr_thin(&Matrix::randn(n, r, &mut rng)).q;
        let ud = Matrix::from_fn(m, r, |i, j| u[(i, j)] / ((j + 1) as f64).powi(2));
        ud.matmul_tr(&v)
    }

    #[test]
    fn optimal_is_best_for_fixed_c_r() {
        let a = decaying_matrix(40, 30, 0);
        let mut rng = Rng::new(1);
        let cols = select_uniform(30, 6, &mut rng);
        let rows = select_uniform(40, 6, &mut rng);
        let opt = cur_optimal(&a, &cols, &rows);
        let dri = cur_drineas08(&a, &cols, &rows);
        let fast = cur_fast(&a, &cols, &rows, FastCurConfig::uniform(24, 24), &mut rng);
        let (e_opt, e_dri, e_fast) =
            (opt.rel_fro_error(&a), dri.rel_fro_error(&a), fast.rel_fro_error(&a));
        assert!(e_opt <= e_fast + 1e-9, "optimal {e_opt} vs fast {e_fast}");
        assert!(e_opt <= e_dri + 1e-9);
        // Fig-2 shape: fast with s=4r is close to optimal, drineas08 is worse
        assert!(e_fast <= e_dri + 1e-9, "fast {e_fast} should beat drineas08 {e_dri}");
    }

    #[test]
    fn fast_cur_entry_count() {
        let a = decaying_matrix(50, 45, 2);
        let mut rng = Rng::new(3);
        let cols = select_uniform(45, 5, &mut rng);
        let rows = select_uniform(50, 5, &mut rng);
        let f = cur_fast(&a, &cols, &rows, FastCurConfig::uniform(20, 20), &mut rng);
        assert!(f.entries_for_u <= 25 * 25);
        let o = cur_optimal(&a, &cols, &rows);
        assert_eq!(o.entries_for_u, 50 * 45);
    }

    #[test]
    fn exact_recovery_low_rank() {
        // rank(A)=3, c=r=5 ⇒ all methods with enough sketch recover exactly
        let mut rng = Rng::new(4);
        let a = gen::low_rank(&mut rng, 30, 25, 3);
        let cols = select_uniform(25, 5, &mut rng);
        let rows = select_uniform(30, 5, &mut rng);
        let opt = cur_optimal(&a, &cols, &rows);
        assert!(opt.rel_fro_error(&a) < 1e-10);
        let fast = cur_fast(&a, &cols, &rows, FastCurConfig::uniform(15, 15), &mut rng);
        assert!(fast.rel_fro_error(&a) < 1e-9, "err={}", fast.rel_fro_error(&a));
    }

    #[test]
    fn leverage_fast_cur_works() {
        let a = decaying_matrix(35, 30, 5);
        let mut rng = Rng::new(6);
        let cols = select_uniform(30, 5, &mut rng);
        let rows = select_uniform(35, 5, &mut rng);
        let f = cur_fast(&a, &cols, &rows, FastCurConfig::leverage(20, 20), &mut rng);
        let e = f.rel_fro_error(&a);
        let e_opt = cur_optimal(&a, &cols, &rows).rel_fro_error(&a);
        assert!(e <= 3.0 * e_opt + 1e-6, "leverage fast {e} vs opt {e_opt}");
    }

    #[test]
    fn streamed_cur_is_bit_identical_to_materialized() {
        let a = decaying_matrix(41, 33, 12); // awkward sizes vs tile heights
        for tile in [1usize, 7, 16, 41] {
            for cfg in [
                FastCurConfig::uniform(18, 18),
                FastCurConfig::leverage(18, 18),
                FastCurConfig::leverage_svd(18, 18),
            ] {
                let mut r1 = Rng::new(77);
                let mut r2 = Rng::new(77);
                let cols = select_uniform(33, 5, &mut r1);
                let rows = select_uniform(41, 5, &mut r1);
                let cols2 = select_uniform(33, 5, &mut r2);
                let rows2 = select_uniform(41, 5, &mut r2);
                assert_eq!(cols, cols2);
                let mat = cur_fast(&a, &cols, &rows, cfg, &mut r1);
                let st = cur_fast_streamed(
                    &a,
                    &cols2,
                    &rows2,
                    cfg,
                    crate::stream::StreamConfig::tiled(tile),
                    &mut r2,
                );
                assert_eq!(mat.c.max_abs_diff(&st.c), 0.0, "C tile={tile}");
                assert_eq!(mat.r.max_abs_diff(&st.r), 0.0, "R tile={tile}");
                assert_eq!(mat.u.max_abs_diff(&st.u), 0.0, "{} U tile={tile}", mat.method);
                assert_eq!(mat.entries_for_u, st.entries_for_u);
            }
        }
    }

    #[test]
    fn resident_cur_is_bit_identical_and_reloads_pass_two() {
        let a = decaying_matrix(41, 33, 12);
        for (budget, tile) in [(0u64, 7usize), (u64::MAX, 7), (0, 16)] {
            for cfg in [FastCurConfig::uniform(18, 18), FastCurConfig::leverage(18, 18)] {
                let mut r1 = Rng::new(77);
                let mut r2 = Rng::new(77);
                let cols = select_uniform(33, 5, &mut r1);
                let rows = select_uniform(41, 5, &mut r1);
                let cols2 = select_uniform(33, 5, &mut r2);
                let rows2 = select_uniform(41, 5, &mut r2);
                let mat = cur_fast(&a, &cols, &rows, cfg, &mut r1);
                let rc = ResidencyConfig::new(budget).with_tile_rows(tile);
                let (st, stats) = cur_fast_streamed_resident(
                    &a,
                    &cols2,
                    &rows2,
                    cfg,
                    StreamConfig::tiled(tile),
                    &rc,
                    &mut r2,
                );
                assert_eq!(mat.c.max_abs_diff(&st.c), 0.0, "C tile={tile}");
                assert_eq!(mat.r.max_abs_diff(&st.r), 0.0, "R tile={tile}");
                assert_eq!(mat.u.max_abs_diff(&st.u), 0.0, "{} U tile={tile}", mat.method);
                let tiles = 41usize.div_ceil(tile) as u64;
                assert_eq!(stats.computes, tiles, "source read once per tile");
                if matches!(cfg.kind, SketchKind::Leverage { .. }) {
                    // pass 2 (the core gather) must come back from residency
                    assert_eq!(stats.hits(), tiles, "budget={budget} tile={tile}");
                    if budget == 0 {
                        assert_eq!(stats.spill_hits, tiles);
                    }
                }
            }
        }
    }

    #[test]
    fn adaptive_improves_over_uniform() {
        // Adaptive column selection should (on average) beat uniform for C.
        let a = decaying_matrix(60, 50, 7);
        let mut e_uni = 0.0;
        let mut e_ada = 0.0;
        for t in 0..5 {
            let mut rng = Rng::new(100 + t);
            let cols_u = select_uniform(50, 9, &mut rng);
            let rows = select_uniform(60, 9, &mut rng);
            e_uni += cur_optimal(&a, &cols_u, &rows).rel_fro_error(&a);
            let cols_a = uniform_adaptive2(&a, 9, &mut rng);
            e_ada += cur_optimal(&a, &cols_a, &rows).rel_fro_error(&a);
        }
        assert!(
            e_ada <= e_uni * 1.1,
            "adaptive ({e_ada}) should be ~at least as good as uniform ({e_uni})"
        );
    }

    #[test]
    fn adaptive_sample_avoids_existing() {
        let a = decaying_matrix(20, 15, 8);
        let mut rng = Rng::new(9);
        let current = vec![0usize, 1, 2];
        let extra = adaptive_sample(&a, &current, 4, &mut rng);
        assert!(extra.iter().all(|e| !current.contains(e)));
    }

    #[test]
    #[should_panic(expected = "column-selection")]
    fn fast_cur_rejects_projection_sketch() {
        let a = decaying_matrix(10, 10, 10);
        let mut rng = Rng::new(11);
        let cfg = FastCurConfig {
            s_c: 5,
            s_r: 5,
            kind: SketchKind::Gaussian,
            force_overlap: false,
            score_basis: CurScoreBasis::Gram,
        };
        cur_fast(&a, &[0, 1], &[0, 1], cfg, &mut rng);
    }
}
