//! SPSD matrix approximation models (paper §3.2 and §4):
//!
//! - [`nystrom`] — `U = W† = (P^T K P)†` (eq. 3),
//! - [`prototype`] — `U* = C† K (C†)^T` (eq. 2, requires all of K),
//! - [`fast`] — `U^fast = (S^T C)† (S^T K S) (C^T S)†` (eq. 5, Algorithm 1).
//!
//! The fast model with a column-selection `S` and the `P ⊂ S` trick
//! (Corollary 5) assembles `S^T K S` from the rows of `C` it already has
//! plus one `(s-c) x (s-c)` oracle block — exactly the paper's Table 3
//! "#entries = nc + (s-c)^2" accounting, which the tests verify through the
//! oracle's entry counter.

pub mod adversarial;
pub mod shift;

use crate::coordinator::oracle::KernelOracle;
use crate::linalg::{gemm, pinv, solve, Matrix};
use crate::sketch::{self, SketchKind, SketchOp};
use crate::stream::{
    run_pipeline, CollectConsumer, ConjugateFold, LeverageFold, LeverageSampler,
    OracleColumnsSource, PrototypeUFold, ResidencyConfig, ResidencyStats, ResidentSource,
    RowGather, SketchFold, StreamConfig, StreamingOracle, TileConsumer, TileSource,
};
use crate::util::{Rng, Stopwatch};

/// A low-rank SPSD approximation `K ≈ C U C^T`.
#[derive(Debug, Clone)]
pub struct SpsdApprox {
    /// n x c sketch.
    pub c: Matrix,
    /// c x c symmetric U matrix.
    pub u: Matrix,
    /// Column indices behind `C` (when `P` was a column selection).
    pub p_indices: Vec<usize>,
    /// Which model produced this ("nystrom" | "prototype" | "fast[...]").
    pub method: String,
    /// Kernel entries the oracle served while building this approximation.
    pub entries_observed: u64,
    /// Wall-clock seconds spent building C and U.
    pub build_secs: f64,
}

impl SpsdApprox {
    /// Materialize the full `C U C^T` (small-n evaluation only). U is
    /// symmetric, so the triangular product halves the dominant n x n gemm.
    pub fn materialize(&self) -> Matrix {
        gemm::symm_nt(&self.c.matmul(&self.u), &self.c)
    }

    /// `‖K - C U C^T‖_F^2 / ‖K‖_F^2` against an explicit K.
    pub fn rel_fro_error(&self, k: &Matrix) -> f64 {
        k.sub(&self.materialize()).fro_norm_sq() / k.fro_norm_sq()
    }

    /// Top-k eigenpairs of `C U C^T` in O(n c^2) (Lemma 10).
    pub fn eig_k(&self, k: usize) -> (Vec<f64>, Matrix) {
        solve::eig_k_of_cuc(&self.c, &self.u, k)
    }

    /// Solve `(C U C^T + alpha I) w = y` in O(n c^2) (Lemma 11).
    pub fn solve_regularized(&self, alpha: f64, y: &[f64]) -> Vec<f64> {
        solve::woodbury_solve(&self.c, &self.u, alpha, y)
    }
}

/// Sample `c` distinct columns uniformly (the paper's default P).
pub fn uniform_p(n: usize, c: usize, rng: &mut Rng) -> Vec<usize> {
    let mut idx = rng.sample_without_replacement(n, c.min(n));
    idx.sort_unstable();
    idx
}

/// Build `C = K[:, P]` and optionally gather `C[rows, :]` in the same
/// pass. The whole-tile config takes the direct `columns` path
/// (bit-identical to the historical materialized build); tiled configs run
/// the bounded double-buffered pipeline, so peak extra memory beyond `C`
/// itself is `O(tile_rows · c)`.
fn build_c_panel(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    stream_cfg: StreamConfig,
    gather: Option<&[usize]>,
) -> (Matrix, Option<Matrix>) {
    let n = oracle.n();
    if stream_cfg.is_whole(n) {
        let c = oracle.columns(p_idx);
        let g = gather.map(|idx| c.select_rows(idx));
        return (c, g);
    }
    let src = OracleColumnsSource::new(oracle, p_idx);
    collect_via(&src, stream_cfg, gather)
}

/// Pipeline-only variant of [`build_c_panel`] over an arbitrary source —
/// the entry point the residency-routed builds share (the source is
/// already a [`ResidentSource`] there, so the materialized `columns`
/// shortcut must not bypass it).
fn collect_via(
    src: &dyn TileSource,
    stream_cfg: StreamConfig,
    gather: Option<&[usize]>,
) -> (Matrix, Option<Matrix>) {
    let n = src.rows();
    let width = src.cols();
    let t = stream_cfg.effective_tile_rows(n);
    let mut collect = CollectConsumer::new(n, width);
    match gather {
        None => {
            run_pipeline(src, t, stream_cfg.queue_depth, &mut [&mut collect]);
            (collect.into_matrix(), None)
        }
        Some(idx) => {
            let mut g = RowGather::new(idx.to_vec(), width);
            run_pipeline(src, t, stream_cfg.queue_depth, &mut [&mut collect, &mut g]);
            (collect.into_matrix(), Some(g.into_matrix()))
        }
    }
}

/// The Nyström method: `U = (P^T C)† = W†`. Observes only the `n x c`
/// column block.
pub fn nystrom(oracle: &dyn KernelOracle, p_idx: &[usize]) -> SpsdApprox {
    nystrom_streamed(oracle, p_idx, StreamConfig::whole())
}

/// Nyström through the tile pipeline: `C` is collected and `W = C[P, :]`
/// gathered in one streamed pass. Bit-identical to [`nystrom`] for every
/// tile size (pure gathers).
pub fn nystrom_streamed(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    stream_cfg: StreamConfig,
) -> SpsdApprox {
    let sw = Stopwatch::start();
    let before = oracle.entries_observed();
    let (c, w) = build_c_panel(oracle, p_idx, stream_cfg, Some(p_idx));
    let w = w.expect("gather requested");
    let mut u = pinv(&w);
    u.symmetrize();
    SpsdApprox {
        c,
        u,
        p_indices: p_idx.to_vec(),
        method: "nystrom".into(),
        entries_observed: oracle.entries_observed() - before,
        build_secs: sw.secs(),
    }
}

/// [`nystrom_streamed`] through the tile residency layer: the `C` pass
/// writes every tile through the LRU/spill arena, so later consumers of
/// the same panel (implicit ops, extra sketch folds) reload instead of
/// re-paying the oracle. Results are bit-identical to [`nystrom`];
/// returns the residency counters alongside the approximation.
pub fn nystrom_resident(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    stream_cfg: StreamConfig,
    residency: &ResidencyConfig,
) -> (SpsdApprox, ResidencyStats) {
    let sw = Stopwatch::start();
    let before = oracle.entries_observed();
    let src = OracleColumnsSource::new(oracle, p_idx);
    let resident = ResidentSource::new(&src, residency);
    let (c, w) = collect_via(&resident, stream_cfg, Some(p_idx));
    let w = w.expect("gather requested");
    let mut u = pinv(&w);
    u.symmetrize();
    let approx = SpsdApprox {
        c,
        u,
        p_indices: p_idx.to_vec(),
        method: "nystrom".into(),
        entries_observed: oracle.entries_observed() - before,
        build_secs: sw.secs(),
    };
    (approx, resident.stats())
}

/// The prototype model: `U* = C† K (C†)^T`. Observes all n^2 entries.
pub fn prototype(oracle: &dyn KernelOracle, p_idx: &[usize]) -> SpsdApprox {
    prototype_streamed(oracle, p_idx, StreamConfig::whole())
}

/// Prototype model through the tile pipeline: the `n x n` kernel flows
/// through `U = Σ_t C†[:, t] (K_t (C†)^T)` one row-tile at a time, so peak
/// extra memory is `O(tile_rows · n + c²)` instead of `O(n²)` — still
/// observing all `n²` entries (that is the model's defining cost), just
/// never storing them. Matches [`prototype`] up to reduction reordering
/// (≤1e-12 relative).
pub fn prototype_streamed(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    stream_cfg: StreamConfig,
) -> SpsdApprox {
    let sw = Stopwatch::start();
    let before = oracle.entries_observed();
    let n = oracle.n();
    let (c, _) = build_c_panel(oracle, p_idx, stream_cfg, None);
    let cp = pinv(&c); // c x n
    let u = if stream_cfg.is_whole(n) {
        let k = oracle.full();
        // (C† K)(C†)^T is symmetric (K is): triangular product + mirror
        // gives an exactly symmetric U at ~half the flops of the full gemm.
        gemm::symm_nt(&cp.matmul(&k), &cp)
    } else {
        let so = StreamingOracle::new(oracle, stream_cfg);
        let mut fold = PrototypeUFold::new(&cp);
        so.stream_full(&mut [&mut fold]);
        fold.into_matrix()
    };
    SpsdApprox {
        c,
        u,
        p_indices: p_idx.to_vec(),
        method: "prototype".into(),
        entries_observed: oracle.entries_observed() - before,
        build_secs: sw.secs(),
    }
}

/// How the leverage family estimates the row-leverage scores of `C`
/// (Gittens & Mahoney 1303.1849 — leverage sampling is what closes the
/// accuracy gap over uniform Nyström; the estimator decides what that
/// accuracy costs in memory).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeverageBasis {
    /// Exact scores from the `c x c` Gram `C^T C`, folded row-by-row while
    /// the `C` tiles stream (default): `O(c²)` score state, bit-identical
    /// results for every tile size.
    Gram,
    /// Sketched Gram surrogate `C^T Ω Ω^T C` from an SRHT `Ω` with `m`
    /// rows, folded in the same pass (`m ≈ 4c` is a good default; `(1±ε)`
    /// scores once `Ω` embeds col(C)). Deterministic per seed, but its
    /// reductions regroup by tile, so streamed results match the
    /// materialized path only to reduction-reordering tolerance.
    Sketched { m: usize },
    /// Reference path: SVD of the resident `C` — the historical behavior,
    /// kept as the accuracy baseline. Needs `O(n·c)` scratch, which is
    /// exactly what the streamed estimators exist to avoid.
    ExactSvd,
}

/// Configuration for the fast model's sketching matrix S.
#[derive(Debug, Clone, Copy)]
pub struct FastConfig {
    /// Target sketch size s (expected, for probabilistic sampling).
    pub s: usize,
    /// Sketching family for S.
    pub kind: SketchKind,
    /// Enforce `P ⊂ S` (Corollary 5; on by default — it both improves
    /// accuracy and enables the (s-c)^2 entry count).
    pub force_p_in_s: bool,
    /// Score estimator for `SketchKind::Leverage` (ignored otherwise).
    pub leverage_basis: LeverageBasis,
}

impl FastConfig {
    pub fn uniform(s: usize) -> Self {
        FastConfig {
            s,
            kind: SketchKind::Uniform,
            force_p_in_s: true,
            leverage_basis: LeverageBasis::Gram,
        }
    }

    pub fn leverage(s: usize) -> Self {
        // Unscaled by default: the paper (§4.5) reports scaling hurts
        // numerical stability in practice.
        FastConfig {
            s,
            kind: SketchKind::Leverage { scaled: false },
            force_p_in_s: true,
            leverage_basis: LeverageBasis::Gram,
        }
    }

    /// Override the leverage score estimator.
    pub fn with_basis(mut self, basis: LeverageBasis) -> Self {
        self.leverage_basis = basis;
        self
    }
}

/// The fast SPSD approximation model (Algorithm 1).
pub fn fast(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    cfg: FastConfig,
    rng: &mut Rng,
) -> SpsdApprox {
    fast_streamed(oracle, p_idx, cfg, StreamConfig::whole(), rng)
}

/// The fast model through the tile pipeline. For uniform selection one
/// streamed pass over `K[:, P]` collects `C` and gathers `C[S, :]`
/// (everything `S^T C` and `S^T K S` need besides the `(s-c)²` fresh
/// oracle block), so peak extra memory beyond the `C` output is
/// `O(tile_rows · c + s²)`. Leverage selection (default
/// [`LeverageBasis::Gram`]) folds its `O(c²)` score state in the same
/// streamed pass and then scores/draws/gathers in one in-memory sweep —
/// same envelope as uniform; see [`LeverageBasis`] for the variants.
/// Projection sketches fold `S^T C` during the `C` pass and `S^T K S`
/// over full-K row tiles — still observing `n²` entries (Table 4) but
/// never storing them.
///
/// With a whole-tile config this *is* the materialized path ([`fast`]
/// delegates here); selection-sketch results are bit-identical across tile
/// sizes, projection sketches match up to reduction reordering.
pub fn fast_streamed(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    cfg: FastConfig,
    stream_cfg: StreamConfig,
    rng: &mut Rng,
) -> SpsdApprox {
    let sw = Stopwatch::start();
    let before = oracle.entries_observed();
    let n = oracle.n();

    let (c_mat, stc, sks) = match cfg.kind {
        SketchKind::Uniform => {
            // S doesn't depend on C: draw it up front so C[S, :] is
            // gathered in the same pass that builds C.
            let op = build_selection_sketch(None, p_idx, cfg, n, rng);
            let (indices, scales) = select_parts(&op);
            let (c_mat, rows_s) = build_c_panel(oracle, p_idx, stream_cfg, Some(&indices));
            let rows_s = rows_s.expect("gather requested");
            let stc = scale_rows(&rows_s, &scales);
            let sks = assemble_sks(oracle, &rows_s, p_idx, &indices, &scales);
            (c_mat, stc, sks)
        }
        SketchKind::Leverage { scaled } => match cfg.leverage_basis {
            LeverageBasis::ExactSvd => {
                // Reference path (the historical behavior): one pass builds
                // C, then scores come from an SVD of the resident panel —
                // `O(n·c)` scratch the streamed estimators avoid.
                let (c_mat, _) = build_c_panel(oracle, p_idx, stream_cfg, None);
                let op = build_selection_sketch(Some(&c_mat), p_idx, cfg, n, rng);
                let (indices, scales) = select_parts(&op);
                let rows_s = c_mat.select_rows(&indices);
                let stc = scale_rows(&rows_s, &scales);
                let sks = assemble_sks(oracle, &rows_s, p_idx, &indices, &scales);
                (c_mat, stc, sks)
            }
            basis => {
                // Streamed two-pass plan. Pass 1: the O(c²) leverage state
                // (row-ordered Gram, or the SRHT surrogate Ω^T C) folds
                // while the C tiles stream — the score computation never
                // needs the n x c panel at once, so beyond the C output the
                // working set is O(tile_rows·c + c²). Pass 2: the sampler
                // sweeps the panel in row order, scoring, drawing and
                // gathering C[S, :] in one pass; here the panel is the
                // build's own (resident) output, so the sweep costs no
                // oracle entries.
                let sk_op;
                let mut collect = CollectConsumer::new(n, p_idx.len());
                let mut fold = match basis {
                    LeverageBasis::Sketched { m } => {
                        sk_op = sketch::srht_sketch(n, m.max(p_idx.len()), rng);
                        LeverageFold::sketched(&sk_op, p_idx.len())
                    }
                    _ => LeverageFold::exact(p_idx.len()),
                };
                let so = StreamingOracle::new(oracle, stream_cfg);
                so.stream_columns(p_idx, &mut [&mut collect, &mut fold]);
                let c_mat = collect.into_matrix();
                let est = fold.into_estimate();

                let s_extra = cfg
                    .s
                    .saturating_sub(if cfg.force_p_in_s { p_idx.len() } else { 0 })
                    .max(1);
                let forced = if cfg.force_p_in_s { p_idx.to_vec() } else { Vec::new() };
                let mut sampler =
                    LeverageSampler::new(&est, s_extra, scaled, forced, n, p_idx.len(), rng);
                sampler.consume(0, &c_mat);
                let (mut indices, mut scales, mut rows_s, sampled) = sampler.into_parts();
                if sampled == 0 {
                    // Degenerate draw (e.g. all-zero scores): one uniform
                    // pick so S is non-empty even without forced indices,
                    // mirroring sketch::leverage — which, like this, may
                    // land inside P, in which case S == P and the build
                    // legitimately degenerates to Nyström for this draw.
                    let pick = rng.usize_below(n);
                    if let Err(pos) = indices.binary_search(&pick) {
                        indices.insert(pos, pick);
                        scales.insert(pos, 1.0);
                        rows_s = c_mat.select_rows(&indices);
                    }
                }
                let stc = scale_rows(&rows_s, &scales);
                let sks = assemble_sks(oracle, &rows_s, p_idx, &indices, &scales);
                (c_mat, stc, sks)
            }
        },
        _ => {
            // Projection sketches need every entry of K (Table 4 —
            // theoretical interest / benchmarking only).
            let op = sketch::build(cfg.kind, n, cfg.s, None, rng);
            if stream_cfg.is_whole(n) {
                let c_mat = oracle.columns(p_idx);
                let k = oracle.full();
                let stc = op.apply_left(&c_mat);
                let mut sks = op.conjugate(&k);
                sks.symmetrize();
                (c_mat, stc, sks)
            } else {
                let so = StreamingOracle::new(oracle, stream_cfg);
                let mut collect = CollectConsumer::new(n, p_idx.len());
                let mut stc_fold = SketchFold::new(&op, p_idx.len());
                so.stream_columns(p_idx, &mut [&mut collect, &mut stc_fold]);
                let mut sks_fold = ConjugateFold::new(&op);
                so.stream_full(&mut [&mut sks_fold]);
                (collect.into_matrix(), stc_fold.into_matrix(), sks_fold.into_matrix())
            }
        }
    };

    let stc_pinv = pinv(&stc); // c x s
    // (S^T C)† (S^T K S) ((S^T C)†)^T is symmetric since S^T K S is.
    let u = gemm::symm_nt(&stc_pinv.matmul(&sks), &stc_pinv);
    SpsdApprox {
        c: c_mat,
        u,
        p_indices: p_idx.to_vec(),
        method: format!("fast[{}]", cfg.kind.name()),
        entries_observed: oracle.entries_observed() - before,
        build_secs: sw.secs(),
    }
}

/// The fast model routed through the tile residency layer (column-selection
/// sketches only — projection sketches stream the full `K`, which is not a
/// reloadable working set). Two things change versus [`fast_streamed`]:
///
/// - every `C` tile goes through a [`ResidentSource`] (LRU + disk spill),
///   so re-reads never re-pay the oracle, and
/// - the leverage family becomes a genuine **two-pass plan over the
///   source**: pass 1 folds only the `O(c²)` score state while tiles write
///   through to the arena; pass 2 reloads tiles — RAM or disk, never the
///   oracle — to collect `C`, score, draw and gather `C[S, :]` in one
///   sweep. The oracle is charged exactly one `n·c` at any RAM budget.
///
/// The rng call sequence is identical to [`fast_streamed`] and the sampler
/// is tile-order invariant, so results are **bit-identical** to the
/// non-resident build (asserted in `tests/residency.rs`).
pub fn fast_streamed_resident(
    oracle: &dyn KernelOracle,
    p_idx: &[usize],
    cfg: FastConfig,
    stream_cfg: StreamConfig,
    residency: &ResidencyConfig,
    rng: &mut Rng,
) -> (SpsdApprox, ResidencyStats) {
    let sw = Stopwatch::start();
    let before = oracle.entries_observed();
    let n = oracle.n();
    let src = OracleColumnsSource::new(oracle, p_idx);
    let resident = ResidentSource::new(&src, residency);
    let t = stream_cfg.effective_tile_rows(n);

    let (c_mat, stc, sks) = match cfg.kind {
        SketchKind::Uniform => {
            let op = build_selection_sketch(None, p_idx, cfg, n, rng);
            let (indices, scales) = select_parts(&op);
            let (c_mat, rows_s) = collect_via(&resident, stream_cfg, Some(&indices));
            let rows_s = rows_s.expect("gather requested");
            let stc = scale_rows(&rows_s, &scales);
            let sks = assemble_sks(oracle, &rows_s, p_idx, &indices, &scales);
            (c_mat, stc, sks)
        }
        SketchKind::Leverage { scaled } => match cfg.leverage_basis {
            LeverageBasis::ExactSvd => {
                let (c_mat, _) = collect_via(&resident, stream_cfg, None);
                let op = build_selection_sketch(Some(&c_mat), p_idx, cfg, n, rng);
                let (indices, scales) = select_parts(&op);
                let rows_s = c_mat.select_rows(&indices);
                let stc = scale_rows(&rows_s, &scales);
                let sks = assemble_sks(oracle, &rows_s, p_idx, &indices, &scales);
                (c_mat, stc, sks)
            }
            basis => {
                // Pass 1: fold only the O(c²) leverage state; tiles write
                // through the residency layer as a side effect.
                let sk_op;
                let mut fold = match basis {
                    LeverageBasis::Sketched { m } => {
                        sk_op = sketch::srht_sketch(n, m.max(p_idx.len()), rng);
                        LeverageFold::sketched(&sk_op, p_idx.len())
                    }
                    _ => LeverageFold::exact(p_idx.len()),
                };
                run_pipeline(&resident, t, stream_cfg.queue_depth, &mut [&mut fold]);
                let est = fold.into_estimate();

                // Pass 2: reload tiles from residency to collect C and run
                // the score/draw/gather sweep — zero new oracle entries.
                let s_extra = cfg
                    .s
                    .saturating_sub(if cfg.force_p_in_s { p_idx.len() } else { 0 })
                    .max(1);
                let forced = if cfg.force_p_in_s { p_idx.to_vec() } else { Vec::new() };
                let mut collect = CollectConsumer::new(n, p_idx.len());
                let mut sampler =
                    LeverageSampler::new(&est, s_extra, scaled, forced, n, p_idx.len(), rng);
                run_pipeline(&resident, t, stream_cfg.queue_depth, &mut [&mut collect, &mut sampler]);
                let c_mat = collect.into_matrix();
                let (mut indices, mut scales, mut rows_s, sampled) = sampler.into_parts();
                if sampled == 0 {
                    // same degenerate-draw fallback as fast_streamed
                    let pick = rng.usize_below(n);
                    if let Err(pos) = indices.binary_search(&pick) {
                        indices.insert(pos, pick);
                        scales.insert(pos, 1.0);
                        rows_s = c_mat.select_rows(&indices);
                    }
                }
                let stc = scale_rows(&rows_s, &scales);
                let sks = assemble_sks(oracle, &rows_s, p_idx, &indices, &scales);
                (c_mat, stc, sks)
            }
        },
        other => panic!(
            "residency routing needs a column-selection sketch, not {}",
            other.name()
        ),
    };

    let stc_pinv = pinv(&stc);
    let u = gemm::symm_nt(&stc_pinv.matmul(&sks), &stc_pinv);
    let approx = SpsdApprox {
        c: c_mat,
        u,
        p_indices: p_idx.to_vec(),
        method: format!("fast[{}]", cfg.kind.name()),
        entries_observed: oracle.entries_observed() - before,
        build_secs: sw.secs(),
    };
    (approx, resident.stats())
}

/// Clone out the index/scale arrays of a column-selection sketch.
fn select_parts(op: &SketchOp) -> (Vec<usize>, Vec<f64>) {
    match op {
        SketchOp::Select { indices, scales, .. } => (indices.clone(), scales.clone()),
        _ => unreachable!("selection sketch expected"),
    }
}

/// `diag(scales) · rows` — the `S^T C` of a column-selection sketch given
/// the already-gathered rows `C[S, :]`. Matches `SketchOp::apply_left`
/// bit-for-bit (same gather, same in-place scaling).
fn scale_rows(rows_s: &Matrix, scales: &[f64]) -> Matrix {
    let mut out = rows_s.clone();
    for (r, &sc) in scales.iter().enumerate() {
        if sc != 1.0 {
            for v in out.row_mut(r) {
                *v *= sc;
            }
        }
    }
    out
}

/// Build the column-selection S for the fast model, honoring `P ⊂ S`.
/// `c_mat` is only consulted for leverage-score sampling.
fn build_selection_sketch(
    c_mat: Option<&Matrix>,
    p_idx: &[usize],
    cfg: FastConfig,
    n: usize,
    rng: &mut Rng,
) -> SketchOp {
    let extra = cfg.s.saturating_sub(if cfg.force_p_in_s { p_idx.len() } else { 0 });
    let op = match cfg.kind {
        SketchKind::Uniform => {
            // Paper §4.5: sample from [n] \ P, then union with P. Unscaled —
            // matching the no-scaling stability trick used for the figures.
            sketch::uniform(n, extra.max(1), false, rng)
        }
        SketchKind::Leverage { scaled } => {
            let scores = sketch::leverage_scores(c_mat.expect("leverage sampling needs C"));
            sketch::leverage(&scores, extra.max(1), scaled, rng)
        }
        _ => unreachable!(),
    };
    if cfg.force_p_in_s {
        sketch::with_forced_indices(op, p_idx)
    } else {
        op
    }
}

/// `S^T K S` for a column-selection S over index set `indices`, reusing the
/// gathered rows `c_s = C[S, :]` for every (i, j) pair where j ∈ P:
/// `K[s_i, p_j] = C[s_i, j] = c_s[i, j]`. Only the `(S \ P) x (S \ P)`
/// block touches the oracle — and only the `s x c` gather (not the full
/// `n x c` panel) is needed here, which is what lets the streamed build
/// drop `C` tiles as soon as they are folded.
fn assemble_sks(
    oracle: &dyn KernelOracle,
    c_s: &Matrix,
    p_idx: &[usize],
    indices: &[usize],
    scales: &[f64],
) -> Matrix {
    let s = indices.len();
    debug_assert_eq!((c_s.rows(), c_s.cols()), (s, p_idx.len()));
    // position of each p in the C columns
    let col_of: std::collections::HashMap<usize, usize> =
        p_idx.iter().enumerate().map(|(j, &p)| (p, j)).collect();
    let mut out = Matrix::zeros(s, s);
    // rows/cols of S covered by C: K[s_r, p] = c_s[r, col_of(p)]
    let in_p: Vec<Option<usize>> = indices.iter().map(|i| col_of.get(i).copied()).collect();
    let fresh: Vec<usize> = (0..s).filter(|&j| in_p[j].is_none()).collect();
    // (a) columns in P (and by symmetry rows in P) come from the gather
    for r in 0..s {
        for (cc, &jpos) in in_p.iter().enumerate() {
            if let Some(cj) = jpos {
                out[(r, cc)] = c_s[(r, cj)];
            }
        }
    }
    for (r, &rpos) in in_p.iter().enumerate() {
        if let Some(cr) = rpos {
            for cc in 0..s {
                out[(r, cc)] = c_s[(cc, cr)];
            }
        }
    }
    // (b) the fresh block needs the oracle
    if !fresh.is_empty() {
        let fresh_idx: Vec<usize> = fresh.iter().map(|&j| indices[j]).collect();
        let block = oracle.block(&fresh_idx, &fresh_idx);
        for (bi, &r) in fresh.iter().enumerate() {
            for (bj, &cc) in fresh.iter().enumerate() {
                out[(r, cc)] = block[(bi, bj)];
            }
        }
    }
    // (c) apply scales: out[i, j] *= scale_i * scale_j
    for i in 0..s {
        if scales[i] != 1.0 {
            let si = scales[i];
            for v in out.row_mut(i) {
                *v *= si;
            }
        }
    }
    for j in 0..s {
        if scales[j] != 1.0 {
            let sj = scales[j];
            for i in 0..s {
                out[(i, j)] *= sj;
            }
        }
    }
    out.symmetrize();
    out
}

/// `min_U ‖K - C U C^T‖_F^2` — the prototype model's objective value, used
/// as the baseline in Theorem 3 style comparisons.
pub fn optimal_objective(k: &Matrix, c: &Matrix) -> f64 {
    let cp = pinv(c);
    let u = gemm::symm_nt(&cp.matmul(k), &cp);
    k.sub(&gemm::symm_nt(&c.matmul(&u), c)).fro_norm_sq()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::oracle::DenseOracle;
    use crate::testkit::gen;

    fn spsd_oracle(n: usize, rank: usize, seed: u64) -> DenseOracle {
        let mut rng = Rng::new(seed);
        DenseOracle::new(gen::spsd(&mut rng, n, rank))
    }

    #[test]
    fn nystrom_entries_and_shape() {
        let o = spsd_oracle(30, 30, 0);
        let mut rng = Rng::new(1);
        let p = uniform_p(30, 6, &mut rng);
        let a = nystrom(&o, &p);
        assert_eq!((a.c.rows(), a.c.cols()), (30, 6));
        assert_eq!((a.u.rows(), a.u.cols()), (6, 6));
        assert_eq!(a.entries_observed, 30 * 6);
    }

    #[test]
    fn prototype_observes_everything_and_is_optimal() {
        let o = spsd_oracle(25, 25, 2);
        let mut rng = Rng::new(3);
        let p = uniform_p(25, 5, &mut rng);
        let a = prototype(&o, &p);
        assert_eq!(a.entries_observed, 25 * 25 + 25 * 5);
        // prototype attains min_U objective
        let err = o.inner().sub(&a.materialize()).fro_norm_sq();
        let opt = optimal_objective(o.inner(), &a.c);
        assert!((err - opt).abs() < 1e-6 * opt.max(1e-9), "err={err} opt={opt}");
    }

    #[test]
    fn fast_entry_count_matches_table3() {
        let n = 40;
        let o = spsd_oracle(n, n, 4);
        let mut rng = Rng::new(5);
        let c = 5;
        let p = uniform_p(n, c, &mut rng);
        let a = fast(&o, &p, FastConfig::uniform(15), &mut rng);
        // entries = n*c (columns) + (s'-c)^2 (fresh block), s' = |S|
        let s_len = {
            // recover |S| from U's construction: entries formula inversion
            let fresh_sq = a.entries_observed - (n * c) as u64;
            (fresh_sq as f64).sqrt() as u64 + c as u64
        };
        assert!(s_len >= c as u64);
        let fresh = s_len - c as u64;
        assert_eq!(a.entries_observed, (n * c) as u64 + fresh * fresh);
        // far fewer than the prototype's n^2
        assert!(a.entries_observed < (n * n) as u64);
    }

    #[test]
    fn fast_error_between_nystrom_and_prototype() {
        // On a decaying-spectrum SPSD matrix, fast (s=4c) should be much
        // closer to prototype than Nyström is, and never worse than ~Nyström.
        let n = 80;
        let mut rng = Rng::new(6);
        // decaying spectrum: G diag(1/i^2) G^T
        let g = crate::linalg::qr::qr_thin(&Matrix::randn(n, n, &mut rng)).q;
        let vals: Vec<f64> = (1..=n).map(|i| 1.0 / (i as f64).powi(2)).collect();
        let gd = Matrix::from_fn(n, n, |i, j| g[(i, j)] * vals[j]);
        let k = gd.matmul_tr(&g);
        let o = DenseOracle::new(k.clone());
        let c = 8;
        let mut err_ny = 0.0;
        let mut err_fast = 0.0;
        let mut err_proto = 0.0;
        let trials = 5;
        for t in 0..trials {
            let mut r = Rng::new(100 + t);
            let p = uniform_p(n, c, &mut r);
            err_ny += nystrom(&o, &p).rel_fro_error(&k);
            err_fast += fast(&o, &p, FastConfig::uniform(4 * c), &mut r).rel_fro_error(&k);
            err_proto += prototype(&o, &p).rel_fro_error(&k);
        }
        err_ny /= trials as f64;
        err_fast /= trials as f64;
        err_proto /= trials as f64;
        assert!(err_proto <= err_fast + 1e-9, "prototype optimal: {err_proto} vs {err_fast}");
        assert!(
            err_fast <= err_ny * 1.05 + 1e-9,
            "fast ({err_fast}) should not be materially worse than nystrom ({err_ny})"
        );
    }

    #[test]
    fn fast_equals_nystrom_when_s_is_p() {
        // S = P (no extra columns, force_p) reduces the fast model to Nyström.
        let o = spsd_oracle(30, 8, 7);
        let mut rng = Rng::new(8);
        let p = uniform_p(30, 6, &mut rng);
        let cfg = FastConfig {
            s: 0,
            kind: SketchKind::Uniform,
            force_p_in_s: true,
            leverage_basis: LeverageBasis::Gram,
        };
        // s=0 extra → sketch falls back to >=1 extra uniform index; instead
        // emulate exactly S=P via a leverage config with zero extras:
        let mut rng2 = Rng::new(9);
        let a_fast = {
            // build with force_p and extra=1, then compare against nystrom
            // only through the optimal-recovery property below instead.
            let _ = cfg;
            fast(&o, &p, FastConfig::uniform(p.len()), &mut rng2)
        };
        let a_ny = nystrom(&o, &p);
        // rank(K)=8 > c=6 so neither is exact, but on the shared subspace
        // both satisfy the same fixed-point equation; check shapes + rough
        // agreement of errors.
        let k = o.inner();
        let e_f = a_fast.rel_fro_error(k);
        let e_n = a_ny.rel_fro_error(k);
        assert!(e_f <= e_n * 1.5 + 1e-9, "fast {e_f} vs nystrom {e_n}");
    }

    #[test]
    fn exact_recovery_when_rank_c_equals_rank_k() {
        // Theorem 6: rank(K) = rank(C) => fast model recovers K exactly.
        let n = 40;
        let r = 5;
        let o = spsd_oracle(n, r, 10);
        let mut rng = Rng::new(11);
        // c > r columns uniformly: C almost surely has rank r = rank(K)
        let p = uniform_p(n, 2 * r, &mut rng);
        for cfg in [FastConfig::uniform(3 * r), FastConfig::leverage(3 * r)] {
            let a = fast(&o, &p, cfg, &mut rng);
            let err = a.rel_fro_error(o.inner());
            assert!(err < 1e-10, "{}: rel err {err}", a.method);
        }
        // Nyström and prototype also recover exactly (known property)
        assert!(nystrom(&o, &p).rel_fro_error(o.inner()) < 1e-10);
        assert!(prototype(&o, &p).rel_fro_error(o.inner()) < 1e-10);
    }

    #[test]
    fn leverage_bases_all_recover_low_rank() {
        // Theorem 6 holds for any S ⊇ P with rank(S^T C) = rank(C), so all
        // three score estimators must recover a low-rank K exactly —
        // including the sketched surrogate, whatever its score noise.
        let n = 40;
        let r = 5;
        let o = spsd_oracle(n, r, 30);
        let mut rng = Rng::new(31);
        let p = uniform_p(n, 2 * r, &mut rng);
        for basis in [
            LeverageBasis::Gram,
            LeverageBasis::Sketched { m: 40 },
            LeverageBasis::ExactSvd,
        ] {
            let cfg = FastConfig::leverage(3 * r).with_basis(basis);
            let a = fast(&o, &p, cfg, &mut rng);
            let err = a.rel_fro_error(o.inner());
            assert!(err < 1e-8, "{basis:?}: rel err {err}");
        }
    }

    #[test]
    fn projection_sketches_work_and_observe_n2() {
        let n = 30;
        let o = spsd_oracle(n, 4, 12);
        let mut rng = Rng::new(13);
        let p = uniform_p(n, 8, &mut rng);
        for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::CountSketch] {
            o.reset_entries();
            let cfg = FastConfig {
                s: 20,
                kind,
                force_p_in_s: false,
                leverage_basis: LeverageBasis::Gram,
            };
            let a = fast(&o, &p, cfg, &mut rng);
            let err = a.rel_fro_error(o.inner());
            assert!(err < 1e-8, "{}: err {err}", kind.name());
            assert!(a.entries_observed >= (n * n) as u64, "{} needs full K", kind.name());
        }
    }

    #[test]
    fn streamed_builds_match_materialized_on_dense_oracle() {
        // Gather-based paths (uniform/leverage fast, nystrom) are
        // bit-identical to the materialized build for every tile size;
        // prototype matches up to reduction reordering.
        let n = 53; // deliberately not divisible by the tile sizes
        let o = spsd_oracle(n, 9, 20);
        let mut rng = Rng::new(21);
        let p = uniform_p(n, 8, &mut rng);
        for tile in [1usize, 7, 16, n] {
            let cfgs = [FastConfig::uniform(20), FastConfig::leverage(20)];
            for cfg in cfgs {
                let mut r1 = Rng::new(99);
                let mut r2 = Rng::new(99);
                let a = fast(&o, &p, cfg, &mut r1);
                let b = fast_streamed(&o, &p, cfg, StreamConfig::tiled(tile), &mut r2);
                assert_eq!(a.c.max_abs_diff(&b.c), 0.0, "{} C tile={tile}", a.method);
                assert_eq!(a.u.max_abs_diff(&b.u), 0.0, "{} U tile={tile}", a.method);
                assert_eq!(a.entries_observed, b.entries_observed, "{} entries", a.method);
            }
            let a = nystrom(&o, &p);
            let b = nystrom_streamed(&o, &p, StreamConfig::tiled(tile));
            assert_eq!(a.c.max_abs_diff(&b.c), 0.0);
            assert_eq!(a.u.max_abs_diff(&b.u), 0.0);

            let a = prototype(&o, &p);
            let b = prototype_streamed(&o, &p, StreamConfig::tiled(tile));
            assert_eq!(a.c.max_abs_diff(&b.c), 0.0);
            let scale = a.u.fro_norm().max(1e-12);
            assert!(
                b.u.sub(&a.u).fro_norm() / scale < 1e-12,
                "prototype U tile={tile}"
            );
            assert_eq!(a.entries_observed, b.entries_observed);
        }
    }

    #[test]
    fn streamed_projection_sketches_match_within_tolerance() {
        let n = 34;
        let o = spsd_oracle(n, 5, 22);
        let p = uniform_p(n, 7, &mut Rng::new(23));
        for kind in [SketchKind::Gaussian, SketchKind::CountSketch, SketchKind::Srht] {
            let cfg = FastConfig {
                s: 18,
                kind,
                force_p_in_s: false,
                leverage_basis: LeverageBasis::Gram,
            };
            let a = fast(&o, &p, cfg, &mut Rng::new(55));
            let b = fast_streamed(&o, &p, cfg, StreamConfig::tiled(9), &mut Rng::new(55));
            let k = o.inner();
            let diff = a.materialize().sub(&b.materialize()).fro_norm() / k.fro_norm();
            assert!(diff < 1e-10, "{}: {diff}", kind.name());
            assert!(b.entries_observed >= (n * n) as u64, "{} must observe n²", kind.name());
        }
    }

    #[test]
    fn eig_k_and_solve_work_through_approx() {
        let o = spsd_oracle(35, 6, 14);
        let mut rng = Rng::new(15);
        let p = uniform_p(35, 12, &mut rng);
        let a = fast(&o, &p, FastConfig::uniform(24), &mut rng);
        let (vals, vecs) = a.eig_k(3);
        assert_eq!(vals.len(), 3);
        assert_eq!((vecs.rows(), vecs.cols()), (35, 3));
        // exact recovery (rank 6 < c) ⇒ eigenvalues match K's
        let ek = crate::linalg::eigh(o.inner());
        for i in 0..3 {
            assert!((vals[i] - ek.values[i]).abs() < 1e-6 * ek.values[0]);
        }
        let y: Vec<f64> = (0..35).map(|i| (i as f64).sin()).collect();
        let w = a.solve_regularized(0.5, &y);
        // check residual of the solve against materialized system
        let mut kk = a.materialize();
        for i in 0..35 {
            kk[(i, i)] += 0.5;
        }
        let resid: f64 = kk
            .matvec(&w)
            .iter()
            .zip(&y)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        assert!(resid < 1e-12, "resid={resid}");
    }
}
