"""Layer-1 Pallas kernel: polynomial kernel block.

    K[i, j] = (gamma * <x_i, y_j> + coef0) ** degree

Extends the library beyond the paper's RBF experiments (any SPSD kernel
works with the fast model). Same tiling story as rbf_block: the inner
product is the MXU-shaped contraction; scale/shift/power are fused VPU
ops. gamma/coef0/degree ride along as (1, 1) operands so one artifact per
shape bucket serves every parameterization.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _poly_block_kernel(gamma_ref, coef0_ref, degree_ref, x_ref, y_ref, o_ref):
    xy = jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    base = gamma_ref[0, 0] * xy + coef0_ref[0, 0]
    o_ref[...] = jnp.power(base, degree_ref[0, 0])


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def poly_block(gamma, coef0, degree, x, y, *, bm: int = 128, bn: int = 128):
    """Polynomial kernel block via the Pallas kernel.

    Args:
      gamma, coef0, degree: (1, 1) f32 kernel parameters.
      x: (m, d), y: (n, d) f32 data blocks; m % bm == n % bn == 0.
    """
    m, d = x.shape
    n, d2 = y.shape
    assert d == d2
    assert m % bm == 0 and n % bn == 0
    grid = (m // bm, n // bn)
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    return pl.pallas_call(
        _poly_block_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            scalar,
            scalar,
            scalar,
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(gamma, coef0, degree, x, y)
