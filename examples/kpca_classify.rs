//! KPCA + 10-NN classification on a synthetic PenDigit-like dataset
//! (paper §6.3.2 / Figs 7-10, k = 3), comparing kernel approximations.
//!
//! ```sh
//! cargo run --release --example kpca_classify -- --scale 0.1 --reps 2
//! ```

use fastspsd::cli::Args;
use fastspsd::figures::{kpca_class, Ctx};

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    argv.insert(0, "fig7".into());
    let args = Args::parse(argv);
    let ctx = Ctx::from_args(&args);
    kpca_class::run(&ctx, &args, 3);
}
