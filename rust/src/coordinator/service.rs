//! The approximation service: the Layer-3 request loop.
//!
//! Clients submit [`ApproxRequest`]s (which model, c, downstream task
//! size k, and optionally an [`ExecPolicy`] — the planner fills the
//! default); the service routes them to a worker pool with a bounded
//! queue (backpressure), each worker builds the approximation against the
//! shared kernel oracle through the unified [`exec`](crate::exec)
//! surface, and replies with eigenvalues plus the run's [`RunMeta`]
//! accounting. The service also meters the **predicted working set of
//! in-flight requests** (`Metrics::mem_in_use`, the sum of
//! `predicted_peak_bytes`): with a [`ServiceConfig::memory_cap`] set,
//! requests that would push the fleet past the cap are shed with an
//! error reply instead of risking the box.

use super::metrics::Metrics;
use super::oracle::{KernelOracle, RbfOracle};
use super::planner;
use crate::cur::{self, FastCurConfig};
use crate::exec::{self, ExecPolicy, RunMeta};
use crate::linalg::svd_thin;
use crate::pool::ThreadPool;
use crate::spsd::{self, FastConfig, LeverageBasis};
use crate::util::Rng;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

pub use super::planner::MethodSpec;

/// One approximation job.
#[derive(Debug, Clone)]
pub struct ApproxRequest {
    pub id: u64,
    pub method: MethodSpec,
    /// sketch size c (columns of C).
    pub c: usize,
    /// downstream top-k eigenpairs to return.
    pub k: usize,
    pub seed: u64,
    /// How to traverse the kernel (`None` = the planner's default,
    /// [`planner::default_policy`]). Spilling
    /// [`Resident`](ExecPolicy::Resident) policies inherit the service's
    /// spill directory unless they pin their own.
    pub policy: Option<ExecPolicy>,
}

/// Reply for one job.
#[derive(Debug, Clone)]
pub struct ApproxResponse {
    pub id: u64,
    pub method: String,
    /// top-k eigenvalues of C U C^T (for `Cur`: top singular values of
    /// the core U).
    pub eigvals: Vec<f64>,
    /// `(rows, cols)` of the CUR core U (only for `Cur` requests).
    pub core_dims: Option<(usize, usize)>,
    /// seconds from submit to completion.
    pub total_secs: f64,
    /// The run's uniform accounting (entries, compute seconds, residency
    /// counters, predicted peak bytes). `None` only on shed requests.
    /// `meta.entries` is a delta read off the oracle's single shared
    /// counter, so with multiple workers a request's figure can absorb
    /// entries from builds that overlap it (exact on a 1-worker service).
    pub meta: Option<RunMeta>,
    /// Why the request was not served (e.g. shed on the memory cap).
    pub error: Option<String>,
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub workers: usize,
    /// max queued jobs before `submit` blocks (backpressure).
    pub queue_capacity: usize,
    /// Directory for residency spill arenas (`None` = the system temp
    /// dir). Arena files are per-request and removed when the build ends.
    pub spill_dir: Option<PathBuf>,
    /// Service-level memory cap in bytes: `submit` sheds (error-replies)
    /// any request whose predicted peak, added to the in-flight sum
    /// (`Metrics::mem_in_use`), would exceed it. `None` = meter but never
    /// shed.
    pub memory_cap: Option<u64>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { workers: 4, queue_capacity: 64, spill_dir: None, memory_cap: None }
    }
}

/// The running service.
pub struct ApproxService {
    oracle: Arc<RbfOracle>,
    pool: ThreadPool,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicU64>,
    spill_dir: Option<PathBuf>,
    memory_cap: Option<u64>,
}

impl ApproxService {
    pub fn new(oracle: Arc<RbfOracle>, cfg: ServiceConfig) -> Self {
        ApproxService {
            oracle,
            pool: ThreadPool::new(cfg.workers.max(1), cfg.queue_capacity.max(1)),
            metrics: Arc::new(Metrics::default()),
            inflight: Arc::new(AtomicU64::new(0)),
            spill_dir: cfg.spill_dir,
            memory_cap: cfg.memory_cap,
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Submit a job; the response is delivered on `reply`. Blocks when the
    /// queue is full; sheds immediately (with an error reply) when the
    /// predicted working set would exceed the memory cap.
    pub fn submit(&self, req: ApproxRequest, reply: mpsc::Sender<ApproxResponse>) {
        self.metrics.requests.inc();
        let n = self.oracle.n();
        let c = req.c.clamp(1, n.max(1));
        let mut policy = req.policy.clone().unwrap_or_else(planner::default_policy);
        if let ExecPolicy::Resident { spill: true, spill_dir, .. } = &mut policy {
            if spill_dir.is_none() {
                *spill_dir = self.spill_dir.clone();
            }
        }
        let predicted = planner::predicted_policy_peak_bytes(n, c, &req.method, &policy);
        let admitted = match self.memory_cap {
            Some(cap) => self.metrics.mem_in_use.try_add_below(predicted, cap),
            None => {
                self.metrics.mem_in_use.add(predicted);
                true
            }
        };
        if !admitted {
            self.metrics.rejected.inc();
            let _ = reply.send(ApproxResponse {
                id: req.id,
                method: req.method.name(),
                eigvals: Vec::new(),
                core_dims: None,
                total_secs: 0.0,
                meta: None,
                error: Some(format!(
                    "shed: predicted working set {predicted} B over the {} B memory cap \
                     ({} B already in flight)",
                    self.memory_cap.unwrap_or(u64::MAX),
                    self.metrics.mem_in_use.get()
                )),
            });
            return;
        }
        self.inflight.fetch_add(1, Ordering::Relaxed);
        let oracle = Arc::clone(&self.oracle);
        let metrics = Arc::clone(&self.metrics);
        let inflight = Arc::clone(&self.inflight);
        let submitted = Instant::now();
        self.pool.submit(move || {
            // Release the admission reservation on every exit path — the
            // pool catches panicking jobs, and a skipped release would
            // permanently shrink the cap's admissible capacity.
            let _guard = ReservationGuard { metrics: &metrics, inflight: &inflight, predicted };
            let started = Instant::now();
            metrics.queue_wait.observe(started.duration_since(submitted));
            let resp = run_request(oracle.as_ref(), &req, c, &policy, predicted, submitted);
            metrics.latency.observe(submitted.elapsed());
            match &resp {
                Ok(_) => metrics.completed.inc(),
                Err(_) => metrics.failed.inc(),
            }
            if let Ok(r) = resp {
                let _ = reply.send(r);
            }
        });
    }

    /// Wait for every submitted job to finish.
    pub fn drain(&self) {
        self.pool.wait_idle();
    }
}

/// Drops the in-flight accounting (memory reservation + inflight count)
/// when a worker job ends — normally or by unwinding through the pool's
/// panic catcher.
struct ReservationGuard<'a> {
    metrics: &'a Metrics,
    inflight: &'a AtomicU64,
    predicted: u64,
}

impl Drop for ReservationGuard<'_> {
    fn drop(&mut self) {
        self.metrics.mem_in_use.sub(self.predicted);
        self.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

fn run_request(
    oracle: &RbfOracle,
    req: &ApproxRequest,
    c: usize,
    policy: &ExecPolicy,
    predicted: u64,
    submitted: Instant,
) -> anyhow::Result<ApproxResponse> {
    let mut rng = Rng::new(req.seed);
    let n = oracle.n();
    let p = spsd::uniform_p(n, c, &mut rng);
    let k_top = req.k.max(1);
    // The response's compute time covers the whole request — kernel
    // materialization (Cur), the build, and the downstream eig/SVD — not
    // just the exec entry point's slice of it.
    let t0 = Instant::now();
    let (eigvals, core_dims, mut meta) = match req.method {
        MethodSpec::Nystrom => {
            let rep = exec::nystrom(oracle, &p, policy);
            (rep.result.eig_k(k_top).0, None, rep.meta)
        }
        MethodSpec::Prototype => {
            let rep = exec::prototype(oracle, &p, policy);
            (rep.result.eig_k(k_top).0, None, rep.meta)
        }
        MethodSpec::Fast { s, kind } => {
            // Gram basis: leverage requests stream with O(c²) score
            // state, matching the peak the planner predicts here.
            let cfg =
                FastConfig { s, kind, force_p_in_s: true, leverage_basis: LeverageBasis::Gram };
            let rep = exec::fast(oracle, &p, cfg, policy, &mut rng);
            (rep.result.eig_k(k_top).0, None, rep.meta)
        }
        MethodSpec::Cur { r, s } => {
            // CUR of the kernel matrix itself: `p` picks the columns, a
            // second uniform draw the rows. Serving materializes K — the
            // n² cost the planner's Cur model predicts and the memory
            // meter charges.
            let before = oracle.entries_observed();
            let kmat = oracle.full();
            let rows = cur::select_uniform(n, r.clamp(1, n), &mut rng);
            let rep =
                exec::cur_fast(&kmat, &p, &rows, FastCurConfig::uniform(s, s), policy, &mut rng);
            let dims = (rep.result.u.rows(), rep.result.u.cols());
            let mut sv = svd_thin(&rep.result.u).s;
            sv.truncate(k_top);
            let mut meta = rep.meta;
            meta.entries = Some(oracle.entries_observed() - before);
            (sv, Some(dims), meta)
        }
    };
    meta.compute_secs = t0.elapsed().as_secs_f64();
    meta.predicted_peak_bytes = Some(predicted);
    Ok(ApproxResponse {
        id: req.id,
        method: req.method.name(),
        eigvals,
        core_dims,
        total_secs: submitted.elapsed().as_secs_f64(),
        meta: Some(meta),
        error: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::sketch::SketchKind;

    fn service(n: usize, workers: usize, cap: usize) -> ApproxService {
        service_cfg(n, ServiceConfig { workers, queue_capacity: cap, ..Default::default() })
    }

    fn service_cfg(n: usize, cfg: ServiceConfig) -> ApproxService {
        let mut rng = Rng::new(0);
        let x = Arc::new(Matrix::randn(n, 6, &mut rng));
        let oracle = Arc::new(RbfOracle::cpu(x, 0.4));
        ApproxService::new(oracle, cfg)
    }

    fn req(id: u64, method: MethodSpec, seed: u64, policy: Option<ExecPolicy>) -> ApproxRequest {
        ApproxRequest { id, method, c: 8, k: 3, seed, policy }
    }

    fn entries_of(r: &ApproxResponse) -> u64 {
        r.meta.as_ref().unwrap().entries.unwrap()
    }

    #[test]
    fn serves_all_methods() {
        // One worker: the per-request entry delta is read off a single
        // shared oracle counter, so overlapping builds would misattribute
        // entries and make the ordering assertions below flaky.
        let svc = service(80, 1, 16);
        let (tx, rx) = mpsc::channel();
        let methods = [
            MethodSpec::Nystrom,
            MethodSpec::Prototype,
            MethodSpec::Fast { s: 24, kind: SketchKind::Uniform },
            MethodSpec::Cur { r: 8, s: 24 },
        ];
        for (i, m) in methods.iter().enumerate() {
            svc.submit(req(i as u64, *m, i as u64, None), tx.clone());
        }
        svc.drain();
        drop(tx);
        let mut resps: Vec<ApproxResponse> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 4);
        for r in &resps {
            assert_eq!(r.eigvals.len(), 3, "{}", r.method);
            assert!(r.eigvals[0] >= r.eigvals[1]);
            assert!(r.error.is_none());
            let meta = r.meta.as_ref().expect("served responses carry meta");
            assert!(meta.compute_secs <= r.total_secs + 1e-9);
            assert!(meta.predicted_peak_bytes.unwrap() > 0);
        }
        // prototype and CUR observe n² + extras; nystrom the fewest
        assert!(entries_of(&resps[1]) > entries_of(&resps[2]));
        assert!(entries_of(&resps[2]) > entries_of(&resps[0]));
        assert!(entries_of(&resps[3]) >= 80 * 80, "served CUR materializes K");
        assert_eq!(resps[3].core_dims, Some((8, 8)), "c x r core");
        assert_eq!(svc.metrics().completed.get(), 4);
        assert_eq!(svc.metrics().failed.get(), 0);
        assert_eq!(svc.metrics().latency.count(), 4);
        assert_eq!(svc.metrics().mem_in_use.get(), 0, "meter must drain to zero");
    }

    #[test]
    fn many_concurrent_requests_complete() {
        let svc = service(60, 4, 8);
        let (tx, rx) = mpsc::channel();
        let total = 30u64;
        for i in 0..total {
            svc.submit(
                req(i, MethodSpec::Fast { s: 16, kind: SketchKind::Uniform }, i, None),
                tx.clone(),
            );
        }
        svc.drain();
        drop(tx);
        assert_eq!(rx.iter().count() as u64, total);
        assert_eq!(svc.metrics().requests.get(), total);
        assert_eq!(svc.inflight(), 0);
        assert_eq!(svc.metrics().mem_in_use.get(), 0);
    }

    #[test]
    fn streamed_requests_match_materialized_results() {
        // The same (method, c, seed) served materialized and through the
        // tile pipeline must agree: bit-identically for the gather-based
        // fast/nystrom paths, to reduction-reordering tolerance for the
        // prototype. One worker: the per-request entry delta is read off a
        // single shared oracle counter, so overlapping builds would
        // misattribute entries and make the equality assertion flaky.
        let svc = service(70, 1, 16);
        let (tx, rx) = mpsc::channel();
        let methods = [
            MethodSpec::Nystrom,
            MethodSpec::Prototype,
            MethodSpec::Fast { s: 20, kind: SketchKind::Uniform },
            MethodSpec::Fast { s: 20, kind: SketchKind::Leverage { scaled: false } },
            MethodSpec::Cur { r: 7, s: 20 },
        ];
        let mut id = 0u64;
        for m in methods {
            for policy in [None, Some(ExecPolicy::streamed(13))] {
                svc.submit(req(id, m, 42, policy), tx.clone());
                id += 1;
            }
        }
        svc.drain();
        drop(tx);
        let mut resps: Vec<ApproxResponse> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 10);
        for pair in resps.chunks(2) {
            let (mat, st) = (&pair[0], &pair[1]);
            assert_eq!(
                entries_of(mat),
                entries_of(st),
                "{}: entry accounting must not change",
                mat.method
            );
            for (a, b) in mat.eigvals.iter().zip(&st.eigvals) {
                let scale = mat.eigvals[0].abs().max(1e-12);
                assert!(
                    (a - b).abs() <= 1e-9 * scale,
                    "{}: streamed eig {b} vs materialized {a}",
                    mat.method
                );
            }
        }
    }

    #[test]
    fn residency_requests_match_plain_and_report_stats() {
        // The same (method, c, seed) with and without residency routing
        // must agree bit-identically (the routed build replays the same
        // rng sequence and gathers the same tiles), carry the same entry
        // count, and attach hit/miss/spill counters. One worker for the
        // same shared-counter reason as above.
        let svc = service(70, 1, 16);
        let (tx, rx) = mpsc::channel();
        let methods = [
            MethodSpec::Nystrom,
            MethodSpec::Fast { s: 20, kind: SketchKind::Uniform },
            MethodSpec::Fast { s: 20, kind: SketchKind::Leverage { scaled: false } },
        ];
        let mut id = 0u64;
        for m in methods {
            for policy in [
                Some(ExecPolicy::streamed(13)),
                Some(ExecPolicy::resident(0).with_tile_rows(13)),
            ] {
                svc.submit(req(id, m, 42, policy), tx.clone());
                id += 1;
            }
        }
        svc.drain();
        drop(tx);
        let mut resps: Vec<ApproxResponse> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 6);
        for pair in resps.chunks(2) {
            let (plain, routed) = (&pair[0], &pair[1]);
            assert!(plain.meta.as_ref().unwrap().residency.is_none());
            let stats = routed
                .meta
                .as_ref()
                .unwrap()
                .residency
                .expect("routed request must report stats");
            assert_eq!(entries_of(plain), entries_of(routed), "{}", plain.method);
            for (a, b) in plain.eigvals.iter().zip(&routed.eigvals) {
                assert_eq!(a, b, "{}: residency must not change results", plain.method);
            }
            assert_eq!(stats.computes, 70u64.div_ceil(13), "one oracle pass per tile");
            if routed.method.contains("leverage") {
                // two-pass plan at a zero RAM budget: pass 2 reads the arena
                assert_eq!(stats.spill_hits, stats.computes, "{}", routed.method);
            }
        }
    }

    #[test]
    fn memory_cap_sheds_over_budget_requests() {
        let n = 80;
        // Cap sized for exactly one materialized nystrom request.
        let one = planner::predicted_policy_peak_bytes(
            n,
            8,
            &MethodSpec::Nystrom,
            &ExecPolicy::Materialized,
        );
        let svc = service_cfg(
            n,
            ServiceConfig {
                workers: 1,
                queue_capacity: 16,
                spill_dir: None,
                memory_cap: Some(one),
            },
        );
        // Deterministic shed: prototype's predicted peak (≥ n²·8) can
        // never fit a cap sized for one nystrom — shed at submit with an
        // error reply, nothing reserved, nothing queued.
        let (tx, rx) = mpsc::channel();
        svc.submit(req(0, MethodSpec::Prototype, 1, None), tx.clone());
        drop(tx);
        let shed: Vec<ApproxResponse> = rx.iter().collect();
        assert_eq!(shed.len(), 1, "shed requests still get a reply");
        let err = shed[0].error.as_ref().expect("over-cap request must be shed");
        assert!(err.contains("shed"), "{err}");
        assert!(shed[0].meta.is_none() && shed[0].eigvals.is_empty());
        assert_eq!(svc.metrics().rejected.get(), 1);
        assert_eq!(svc.metrics().mem_in_use.get(), 0, "a shed reserves nothing");

        // A burst of fitting requests: admission is first-come with the
        // in-flight sum, so every reply is either served (meta) or shed
        // (error), the accounting balances, and the meter drains to zero.
        let (tx, rx) = mpsc::channel();
        let total = 10u64;
        for i in 0..total {
            svc.submit(req(i, MethodSpec::Nystrom, i, None), tx.clone());
        }
        svc.drain();
        drop(tx);
        let resps: Vec<ApproxResponse> = rx.iter().collect();
        assert_eq!(resps.len(), total as usize);
        for r in &resps {
            assert!(
                r.error.is_some() ^ r.meta.is_some(),
                "{}: exactly one of error/meta",
                r.id
            );
        }
        let served = resps.iter().filter(|r| r.meta.is_some()).count() as u64;
        assert!(served >= 1, "the first request always fits an empty meter");
        assert_eq!(svc.metrics().completed.get(), served);
        assert_eq!(svc.metrics().rejected.get(), 1 + (total - served));
        assert_eq!(svc.metrics().mem_in_use.get(), 0);
        assert_eq!(svc.inflight(), 0);

        // Uncapped services meter without shedding.
        let svc = service(40, 1, 8);
        let (tx, rx) = mpsc::channel();
        svc.submit(req(0, MethodSpec::Prototype, 1, None), tx);
        svc.drain();
        assert!(rx.iter().next().unwrap().error.is_none());
        assert_eq!(svc.metrics().rejected.get(), 0);
        assert_eq!(svc.metrics().mem_in_use.get(), 0);
    }
}
