//! Tile residency engine: an in-RAM **LRU of hot tiles** plus a **disk
//! spill arena**, so cold tiles are *reloaded*, never *recomputed*.
//!
//! The implicit operators ([`implicit`](super::implicit)) and every
//! multi-pass plan (two-pass leverage, repeated sketch folds over the same
//! `C`) re-request the same kernel tiles; without a residency layer each
//! pass re-charges the oracle, so `q` Lanczos iterations cost `q·n·c`
//! kernel evaluations instead of one. [`ResidentSource`] wraps any
//! [`TileSource`] with:
//!
//! - a **hot-tile LRU** holding at most `ram_budget` bytes of tiles (the
//!   planner's [`Goal::memory_budget`] unit — see
//!   [`plan_residency`](crate::coordinator::planner::plan_residency) for
//!   picking the tile_rows/budget split). Admission is scan-resistant
//!   (see `ResidentSource::admit`): cyclic multi-pass workloads keep a
//!   stable hot set and hit at ≈ `ram_budget / panel` instead of
//!   LRU-thrashing to zero, and
//! - a **spill arena**: one append-only temp file of serialized tiles with
//!   an offset index. Tiles are written through on first compute and read
//!   back on a RAM miss, so the underlying source is consulted **exactly
//!   once per tile** no matter how many passes run — with a 0-byte RAM
//!   budget every re-read comes from disk, and `n` larger than RAM only
//!   needs the arena to fit on disk.
//!
//! Tiles round-trip through the arena bit-exactly in either element width
//! (`f64`/`f32` ↔ little-endian bytes; each arena record is framed by the
//! checksummed codec in [`record`](super::record): a 1-byte width tag plus
//! an 8-byte XXH64 digest of the payload, verified on every read-back), so
//! residency-served results are **bit-identical** to the recompute path.
//! An f32-configured layer ([`ResidencyConfig::precision`])
//! caches and spills tiles at half the bytes per entry — the same panel
//! fits twice over in the same `ram_budget`, and
//! [`ResidencyStats::spilled_bytes`] (payload bytes, headers excluded)
//! halves. The arena file is removed by a guard object when the
//! source is dropped — including during a panic unwind. If the filesystem
//! fails, writes and reads are first retried with a short exponential
//! backoff (transient IO errors recover invisibly —
//! [`ResidencyStats::io_retries`] counts them); a persistently failing
//! arena is then dropped and the layer degrades to recompute-on-miss
//! instead of erroring. A record whose checksum (or width tag) disagrees
//! with the bytes read back is **not retried** — the bytes are wrong, not
//! the IO — it bumps [`ResidencyStats::corrupt_reads`], invalidates only
//! that record's offset, and recomputes the one tile (a fresh record is
//! written through), so corruption costs one oracle charge, never wrong
//! bits: residency is a performance layer, never a
//! correctness dependency. The chaos harness
//! ([`testkit::faults`](crate::testkit::faults)) injects failures into
//! exactly these seams, including write-time record corruption
//! ([`FaultPoint::SpillCorrupt`]).
//!
//! Requests do not need to align with the residency grid
//! ([`ResidencyConfig::tile_rows`]): arbitrary `[r0, r1)` ranges are
//! assembled from the grid tiles they overlap. Aligned requests (grid ==
//! pipeline tile height, the default the wrappers pick) avoid computing
//! rows outside the request on a cold miss.
//!
//! [`Goal::memory_budget`]: crate::coordinator::planner::Goal

use super::record::{self, RECORD_HEADER_BYTES};
use super::TileSource;
use crate::linalg::{Matrix, MatrixF32, Precision, Tile};
use crate::obs::{self, Stage};
use crate::testkit::faults::{self, FaultPoint};
use std::fs::File;
use std::io::{Read as _, Seek, SeekFrom, Write as _};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Default residency grid height: matches the stream bench's default tile
/// and the AOT kernel artifacts' 256-row blocks.
pub const DEFAULT_RESIDENT_TILE_ROWS: usize = 256;

/// How a [`ResidentSource`] caches and spills.
#[derive(Debug, Clone)]
pub struct ResidencyConfig {
    /// Max bytes of tiles held hot in RAM (0 = every re-read hits disk).
    pub ram_budget: u64,
    /// Grid height of cached/spilled tiles. Wrappers set this to the
    /// pipeline's tile height so requests align with the grid.
    pub tile_rows: usize,
    /// Write tiles through to a disk arena on first compute (on by
    /// default — this is what makes re-reads free at any RAM budget).
    pub spill: bool,
    /// Directory for the arena file (`None` = the system temp dir).
    pub spill_dir: Option<PathBuf>,
    /// Element width tiles are cached and spilled at. `F32` halves the
    /// bytes per entry in both the RAM LRU and the arena; `F64` (the
    /// default) is byte-for-byte the pre-precision behavior.
    pub precision: Precision,
}

impl ResidencyConfig {
    /// LRU of `ram_budget` bytes + disk spill in the system temp dir.
    pub fn new(ram_budget: u64) -> Self {
        ResidencyConfig {
            ram_budget,
            tile_rows: DEFAULT_RESIDENT_TILE_ROWS,
            spill: true,
            spill_dir: None,
            precision: Precision::F64,
        }
    }

    /// RAM-only residency: no arena, evicted tiles are recomputed. This is
    /// the budget-gated cached-`C` semantics the `*_budgeted` implicit ops
    /// keep (same gate as [`CachingSource`](super::CachingSource)).
    pub fn ram_only(ram_budget: u64) -> Self {
        ResidencyConfig { spill: false, ..ResidencyConfig::new(ram_budget) }
    }

    /// Everything stays hot (tests / panels known to fit).
    pub fn unbounded() -> Self {
        ResidencyConfig::ram_only(u64::MAX)
    }

    pub fn with_tile_rows(mut self, tile_rows: usize) -> Self {
        self.tile_rows = tile_rows.max(1);
        self
    }

    pub fn with_spill_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.spill_dir = Some(dir.into());
        self.spill = true;
        self
    }

    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }
}

/// Counters a [`ResidentSource`] keeps (returned by
/// [`ResidentSource::stats`], carried in service responses).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Grid-tile requests served from the RAM LRU.
    pub ram_hits: u64,
    /// Grid-tile requests served by reading the spill arena.
    pub spill_hits: u64,
    /// Grid tiles computed via the inner source (the oracle charges).
    pub computes: u64,
    /// Bytes appended to the spill arena.
    pub spilled_bytes: u64,
    /// Tiles dropped from the RAM LRU to respect the budget.
    pub evictions: u64,
    /// Spill IO operations retried after a transient failure (each retry
    /// that was attempted counts once, whether or not it succeeded).
    pub io_retries: u64,
    /// Arena records whose checksum or width tag failed verification on
    /// read-back. Each one invalidated a single record and recomputed
    /// that tile — corruption is detected, never folded.
    pub corrupt_reads: u64,
}

impl ResidencyStats {
    /// Requests that avoided recomputing the inner source.
    pub fn hits(&self) -> u64 {
        self.ram_hits + self.spill_hits
    }

    /// Fold another worker's counters into this one. Every field is an
    /// event count, so the shard coordinator can sum per-worker stats
    /// into one request-level view.
    pub fn absorb(&mut self, other: &ResidencyStats) {
        self.ram_hits += other.ram_hits;
        self.spill_hits += other.spill_hits;
        self.computes += other.computes;
        self.spilled_bytes += other.spilled_bytes;
        self.evictions += other.evictions;
        self.io_retries += other.io_retries;
        self.corrupt_reads += other.corrupt_reads;
    }
}

/// Removes the arena file when dropped — a guard object, so the temp file
/// is cleaned up even when a pipeline consumer panics and unwinds through
/// the owning [`ResidentSource`].
struct SpillGuard {
    path: PathBuf,
}

impl Drop for SpillGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// The append-only tile arena. Field order matters: the handle closes
/// before the guard unlinks the path.
///
/// The chaos plan is **not** captured here: every IO attempt re-reads
/// [`faults::current`], so a plan armed mid-run (a service retry arming
/// injection after the arena came up) is honored from its next operation.
struct SpillArena {
    file: File,
    /// Next append offset.
    next: u64,
    guard: SpillGuard,
}

/// Process-wide arena name sequence (several sources may spill at once).
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

fn create_arena(dir: Option<&Path>) -> Option<SpillArena> {
    let dir = dir.map(Path::to_path_buf).unwrap_or_else(std::env::temp_dir);
    let seq = SPILL_SEQ.fetch_add(1, Ordering::Relaxed);
    let path = dir.join(format!("fastspsd-spill-{}-{seq}.tiles", std::process::id()));
    let file = File::options().read(true).write(true).create_new(true).open(&path).ok()?;
    Some(SpillArena { file, next: 0, guard: SpillGuard { path } })
}

/// Append `t` to the arena as a checksummed [`record`] (width tag +
/// XXH64 digest + row-major little-endian payload); `None` = IO failure
/// (the caller retries, then degrades to recompute-on-miss).
fn write_tile(arena: &mut SpillArena, t: &Tile) -> Option<u64> {
    let plan = faults::current();
    if let Some(plan) = &plan {
        if plan.should_fail(FaultPoint::SpillWrite) {
            return None; // injected ENOSPC-style write failure
        }
    }
    let off = arena.next;
    arena.file.seek(SeekFrom::Start(off)).ok()?;
    let mut buf = record::encode(record::width_tag(t.precision()), &record::tile_payload(t));
    if let Some(plan) = &plan {
        if plan.should_fail(FaultPoint::SpillCorrupt) {
            // silent bit rot: the digest stays stale, so read-back
            // deterministically detects the flip
            record::corrupt_in_place(&mut buf);
        }
    }
    arena.file.write_all(&buf).ok()?;
    arena.next = off + buf.len() as u64;
    Some(off)
}

/// Why a spill read did not produce a tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SpillReadError {
    /// The read itself failed (short read, IO error, injected fault) —
    /// worth retrying, and grounds for dropping the arena if persistent.
    Io,
    /// The bytes came back but failed checksum/tag verification —
    /// retrying would re-read the same wrong bytes, so the caller
    /// invalidates the record and recomputes the tile instead.
    Corrupt,
}

/// Read a `rows x cols` tile back (bit-exact round trip per element
/// width), verifying the record checksum. A width tag that disagrees
/// with `prec` or a digest that disagrees with the payload is
/// [`SpillReadError::Corrupt`] — never reinterpret or fold wrong bytes.
fn read_tile(
    arena: &mut SpillArena,
    off: u64,
    rows: usize,
    cols: usize,
    prec: Precision,
) -> Result<Tile, SpillReadError> {
    if let Some(plan) = faults::current() {
        if plan.should_fail(FaultPoint::SpillRead) {
            return Err(SpillReadError::Io); // injected short read / IO error
        }
    }
    arena.file.seek(SeekFrom::Start(off)).map_err(|_| SpillReadError::Io)?;
    let mut header = [0u8; RECORD_HEADER_BYTES];
    arena.file.read_exact(&mut header).map_err(|_| SpillReadError::Io)?;
    let mut buf = vec![0u8; rows * cols * prec.bytes()];
    arena.file.read_exact(&mut buf).map_err(|_| SpillReadError::Io)?;
    record::verify(record::width_tag(prec), &header, &buf).map_err(|_| SpillReadError::Corrupt)?;
    Ok(record::tile_from_payload(rows, cols, prec, &buf))
}

/// Spill IO attempts per operation: one try + up to two retries with a
/// short exponential backoff. Transient failures (one bad write or read)
/// recover invisibly; persistent ones exhaust the attempts and fall into
/// the existing degrade-to-recompute path.
const SPILL_IO_ATTEMPTS: u32 = 3;

fn backoff(attempt: u32) {
    std::thread::sleep(std::time::Duration::from_micros(50 << (attempt - 1)));
}

/// [`write_tile`] with retries; returns the offset (if any) and how many
/// retries were taken (for [`ResidencyStats::io_retries`]).
fn write_tile_retrying(arena: &mut SpillArena, m: &Tile) -> (Option<u64>, u64) {
    let mut retries = 0;
    for attempt in 0..SPILL_IO_ATTEMPTS {
        if attempt > 0 {
            retries += 1;
            backoff(attempt);
        }
        // one span per attempt, so injected-fault retries show up as
        // repeated residency.spill_write events in the trace
        let _s = obs::span(Stage::ResidencySpillWrite);
        if let Some(off) = write_tile(arena, m) {
            return (Some(off), retries);
        }
    }
    (None, retries)
}

/// [`read_tile`] with retries; same contract as [`write_tile_retrying`],
/// except a [`SpillReadError::Corrupt`] result returns immediately —
/// the bytes are deterministic, a retry would re-read the same
/// corruption.
fn read_tile_retrying(
    arena: &mut SpillArena,
    off: u64,
    rows: usize,
    cols: usize,
    prec: Precision,
) -> (Result<Tile, SpillReadError>, u64) {
    let mut retries = 0;
    let mut last = SpillReadError::Io;
    for attempt in 0..SPILL_IO_ATTEMPTS {
        if attempt > 0 {
            retries += 1;
            backoff(attempt);
        }
        let _s = obs::span(Stage::ResidencySpillRead);
        match read_tile(arena, off, rows, cols, prec) {
            Ok(m) => return (Ok(m), retries),
            Err(SpillReadError::Corrupt) => return (Err(SpillReadError::Corrupt), retries),
            Err(e) => last = e,
        }
    }
    (Err(last), retries)
}

struct Slot {
    ram: Option<Tile>,
    /// Last-use tick while resident (the LRU eviction key).
    stamp: u64,
    /// Lifetime access count (the admission key — see `ResidentSource::admit`).
    uses: u64,
    /// Byte offset in the arena once written through.
    spill_off: Option<u64>,
}

struct ResState {
    slots: Vec<Slot>,
    tick: u64,
    ram_bytes: u64,
    arena: Option<SpillArena>,
    stats: ResidencyStats,
}

/// A [`TileSource`] wrapper that makes repeated tile access pay the inner
/// source exactly once per tile (see the module docs).
pub struct ResidentSource<'a> {
    inner: &'a dyn TileSource,
    grid: usize,
    ram_budget: u64,
    precision: Precision,
    state: Mutex<ResState>,
}

impl<'a> ResidentSource<'a> {
    pub fn new(inner: &'a dyn TileSource, cfg: &ResidencyConfig) -> Self {
        let n = inner.rows();
        let grid = cfg.tile_rows.clamp(1, n.max(1));
        let tiles = n.div_ceil(grid);
        let arena = if cfg.spill && n > 0 {
            create_arena(cfg.spill_dir.as_deref())
        } else {
            None
        };
        let slots = (0..tiles)
            .map(|_| Slot { ram: None, stamp: 0, uses: 0, spill_off: None })
            .collect();
        ResidentSource {
            inner,
            grid,
            ram_budget: cfg.ram_budget,
            precision: cfg.precision,
            state: Mutex::new(ResState { slots, tick: 0, ram_bytes: 0, arena, stats: ResidencyStats::default() }),
        }
    }

    /// Element width this layer caches and spills at.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Snapshot of the hit/miss/spill counters.
    pub fn stats(&self) -> ResidencyStats {
        self.state.lock().unwrap().stats
    }

    /// The residency grid height (requests are assembled from these tiles).
    pub fn grid_rows(&self) -> usize {
        self.grid
    }

    /// Whether a spill arena is live (requested AND the filesystem
    /// cooperated so far).
    pub fn spill_active(&self) -> bool {
        self.state.lock().unwrap().arena.is_some()
    }

    /// Path of the arena file while it is live (tests assert cleanup).
    pub fn spill_path(&self) -> Option<PathBuf> {
        self.state
            .lock()
            .unwrap()
            .arena
            .as_ref()
            .map(|a| a.guard.path.clone())
    }

    fn bounds(&self, g: usize) -> (usize, usize) {
        let t0 = g * self.grid;
        (t0, (t0 + self.grid).min(self.inner.rows()))
    }

    /// Serve grid tile `g` to `f`: RAM hit, spill read, or compute (in
    /// that order), write-through + cache admission on the way.
    fn with_grid_tile(&self, g: usize, f: impl FnOnce(&Tile)) {
        let (t0, t1) = self.bounds(g);
        let mut st = self.state.lock().unwrap();
        st.tick += 1;
        let tick = st.tick;
        st.slots[g].uses += 1;
        if st.slots[g].ram.is_some() {
            let _s = obs::span(Stage::ResidencyRamHit);
            st.slots[g].stamp = tick;
            st.stats.ram_hits += 1;
            f(st.slots[g].ram.as_ref().unwrap());
            return;
        }
        let m = self.fetch_cold(&mut st, g, t0, t1);
        let bytes = m.payload_bytes();
        if self.admit(&mut st, g, bytes) {
            st.ram_bytes += bytes;
            st.slots[g].ram = Some(m);
            st.slots[g].stamp = tick;
            f(st.slots[g].ram.as_ref().unwrap());
        } else {
            f(&m);
        }
    }

    /// Owned variant of [`Self::with_grid_tile`] for requests that cover
    /// exactly one grid tile (the common case — the wrappers align the
    /// grid with the pipeline tile height): an unadmitted cold tile is
    /// returned by move, so the zero-cache path costs no more copies than
    /// a plain passthrough.
    fn take_grid_tile(&self, g: usize) -> Tile {
        let (t0, t1) = self.bounds(g);
        let mut st = self.state.lock().unwrap();
        st.tick += 1;
        let tick = st.tick;
        st.slots[g].uses += 1;
        if st.slots[g].ram.is_some() {
            let _s = obs::span(Stage::ResidencyRamHit);
            let out = st.slots[g].ram.as_ref().unwrap().clone();
            st.slots[g].stamp = tick;
            st.stats.ram_hits += 1;
            return out;
        }
        let m = self.fetch_cold(&mut st, g, t0, t1);
        let bytes = m.payload_bytes();
        if self.admit(&mut st, g, bytes) {
            st.ram_bytes += bytes;
            st.slots[g].stamp = tick;
            let out = m.clone();
            st.slots[g].ram = Some(m);
            out
        } else {
            m
        }
    }

    /// Fetch a non-resident grid tile: spill read when the arena has it,
    /// compute (+ write-through) otherwise. IO failures are retried with
    /// backoff first; an arena that still fails is dropped wholesale —
    /// every recorded offset becomes recompute. A *corrupt* record (bad
    /// checksum or width tag) invalidates only its own offset: the arena
    /// stays live, this one tile recomputes and writes a fresh record.
    fn fetch_cold(&self, st: &mut ResState, g: usize, t0: usize, t1: usize) -> Tile {
        let spilled = st.slots[g].spill_off.filter(|_| st.arena.is_some());
        if let Some(off) = spilled {
            let (m, retries) = read_tile_retrying(
                st.arena.as_mut().unwrap(),
                off,
                t1 - t0,
                self.inner.cols(),
                self.precision,
            );
            st.stats.io_retries += retries;
            match m {
                Ok(m) => {
                    st.stats.spill_hits += 1;
                    return m;
                }
                Err(SpillReadError::Corrupt) => {
                    st.stats.corrupt_reads += 1;
                    st.slots[g].spill_off = None;
                }
                Err(SpillReadError::Io) => {
                    st.arena = None;
                    for s in st.slots.iter_mut() {
                        s.spill_off = None;
                    }
                }
            }
        }
        self.compute_tile(st, g, t0, t1)
    }

    /// Compute grid tile `g` from the inner source and write it through to
    /// the arena. Runs under the state lock: tile production is already
    /// serialized per pipeline (one producer), and inner-source compute
    /// parallelism lives below this layer (the oracle's GEMM pool).
    fn compute_tile(&self, st: &mut ResState, g: usize, t0: usize, t1: usize) -> Tile {
        let m = {
            let _s = obs::span(Stage::ResidencyRecompute);
            self.inner.tile_elem(t0, t1, self.precision)
        };
        st.stats.computes += 1;
        if st.slots[g].spill_off.is_none() {
            if let Some(arena) = st.arena.as_mut() {
                let (wrote, retries) = write_tile_retrying(arena, &m);
                st.stats.io_retries += retries;
                match wrote {
                    Some(off) => {
                        st.slots[g].spill_off = Some(off);
                        st.stats.spilled_bytes += m.payload_bytes();
                    }
                    None => {
                        // write failed even after retries: degrade to
                        // recompute-on-miss
                        st.arena = None;
                        for s in st.slots.iter_mut() {
                            s.spill_off = None;
                        }
                    }
                }
            }
        }
        m
    }

    /// Scan-resistant admission over the LRU: a tile is admitted while
    /// free budget remains; once the cache is full it may only displace
    /// least-recently-used victims it has strictly out-accessed
    /// (TinyLFU-style frequency gate). Plain LRU admission would thrash
    /// on the cyclic re-scans every consumer of this layer runs (the tile
    /// about to be revisited is always the one just evicted — 0% hits at
    /// any budget below the panel); with the gate, cyclic scans converge
    /// on a stable hot set of the first tiles that fit, so the RAM hit
    /// rate is ≈ `ram_budget / panel` — the model
    /// [`plan_residency`](crate::coordinator::planner::plan_residency)
    /// predicts — while genuinely hotter tiles still displace colder
    /// ones. The O(slots) victim scan runs only on displacement, which
    /// cyclic scans never trigger; spilled victims make eviction free
    /// (the bytes are already on disk).
    fn admit(&self, st: &mut ResState, g: usize, bytes: u64) -> bool {
        if bytes > self.ram_budget {
            return false; // can never fit, even alone
        }
        if st.ram_bytes + bytes <= self.ram_budget {
            return true; // free budget remains, no displacement needed
        }
        // Plan the displacement before touching anything, so a rejected
        // admission never shrinks the hot set: victims are taken
        // coldest-first and every one must pass the frequency gate.
        let uses_g = st.slots[g].uses;
        let mut candidates: Vec<usize> = (0..st.slots.len())
            .filter(|&i| st.slots[i].ram.is_some())
            .collect();
        candidates.sort_by_key(|&i| st.slots[i].stamp);
        let mut freed = 0u64;
        let mut victims = Vec::new();
        for &i in &candidates {
            if st.ram_bytes - freed + bytes <= self.ram_budget {
                break;
            }
            if st.slots[i].uses >= uses_g {
                return false; // would displace a tile at least as hot
            }
            freed += st.slots[i].ram.as_ref().unwrap().payload_bytes();
            victims.push(i);
        }
        if st.ram_bytes - freed + bytes > self.ram_budget {
            return false; // even evicting every colder tile is not enough
        }
        for &v in &victims {
            let m = st.slots[v].ram.take().unwrap();
            st.ram_bytes -= m.payload_bytes();
            st.stats.evictions += 1;
        }
        true
    }
}

impl ResidentSource<'_> {
    /// Serve `[r0, r1)` at the layer's configured precision (the cache is
    /// homogeneous — every slot and arena record holds one element width).
    fn tile_native(&self, r0: usize, r1: usize) -> Tile {
        let n = self.inner.rows();
        if r1 <= r0 || n == 0 {
            return self.inner.tile_elem(r0, r1, self.precision);
        }
        debug_assert!(r1 <= n, "tile request past the source");
        let cols = self.inner.cols();
        let g0 = r0 / self.grid;
        let g1 = (r1 - 1) / self.grid;
        if g0 == g1 && (r0, r1) == self.bounds(g0) {
            // grid-aligned request: hand the tile over whole
            return self.take_grid_tile(g0);
        }
        let mut out = match self.precision {
            Precision::F64 => Tile::F64(Matrix::zeros(r1 - r0, cols)),
            Precision::F32 => Tile::F32(MatrixF32::zeros(r1 - r0, cols)),
        };
        for g in g0..=g1 {
            let (t0, t1) = self.bounds(g);
            self.with_grid_tile(g, |tile| {
                let lo = r0.max(t0);
                let hi = r1.min(t1);
                match (&mut out, tile) {
                    (Tile::F64(o), Tile::F64(m)) => {
                        for i in lo..hi {
                            o.row_mut(i - r0).copy_from_slice(m.row(i - t0));
                        }
                    }
                    (Tile::F32(o), Tile::F32(m)) => {
                        for i in lo..hi {
                            o.row_mut(i - r0).copy_from_slice(m.row(i - t0));
                        }
                    }
                    _ => unreachable!("residency cache is width-homogeneous"),
                }
            });
        }
        out
    }
}

impl TileSource for ResidentSource<'_> {
    fn rows(&self) -> usize {
        self.inner.rows()
    }

    fn cols(&self) -> usize {
        self.inner.cols()
    }

    fn tile(&self, r0: usize, r1: usize) -> Matrix {
        match self.tile_native(r0, r1) {
            Tile::F64(m) => m,
            // exact, so an f32-resident layer still serves f64 callers
            Tile::F32(m) => m.promote(),
        }
    }

    fn tile_f32(&self, r0: usize, r1: usize) -> MatrixF32 {
        match self.tile_native(r0, r1) {
            Tile::F32(m) => m,
            Tile::F64(m) => m.demote(),
        }
    }

    fn tile_elem(&self, r0: usize, r1: usize, prec: Precision) -> Tile {
        match (prec, self.tile_native(r0, r1)) {
            (Precision::F64, Tile::F32(m)) => Tile::F64(m.promote()),
            (Precision::F32, Tile::F64(m)) => Tile::F32(m.demote()),
            (_, t) => t,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::{run_pipeline, CollectConsumer, MatrixSource, TileConsumer};
    use crate::util::Rng;
    use std::sync::atomic::AtomicUsize;

    /// Counts how many times each grid tile was computed.
    struct CountingInner {
        a: Matrix,
        computes: AtomicUsize,
    }

    impl TileSource for CountingInner {
        fn rows(&self) -> usize {
            self.a.rows()
        }
        fn cols(&self) -> usize {
            self.a.cols()
        }
        fn tile(&self, r0: usize, r1: usize) -> Matrix {
            self.computes.fetch_add(1, Ordering::SeqCst);
            self.a.block(r0, r1, 0, self.a.cols())
        }
    }

    fn counting(n: usize, c: usize, seed: u64) -> CountingInner {
        let mut rng = Rng::new(seed);
        CountingInner { a: Matrix::randn(n, c, &mut rng), computes: AtomicUsize::new(0) }
    }

    #[test]
    fn unaligned_requests_assemble_bit_exactly() {
        let inner = counting(29, 3, 0);
        for (ram, spill) in [(u64::MAX, false), (0, true), (29 * 3 * 8 / 2, true)] {
            let mut cfg = ResidencyConfig::new(ram).with_tile_rows(8);
            cfg.spill = spill;
            let src = ResidentSource::new(&inner, &cfg);
            // deliberately misaligned and overlapping ranges
            for (r0, r1) in [(0usize, 29usize), (3, 11), (7, 8), (15, 29), (0, 1)] {
                let got = src.tile(r0, r1);
                assert_eq!(
                    got.max_abs_diff(&inner.a.block(r0, r1, 0, 3)),
                    0.0,
                    "[{r0},{r1}) ram={ram} spill={spill}"
                );
            }
        }
    }

    #[test]
    fn spill_pays_the_source_exactly_once_at_zero_ram() {
        let inner = counting(40, 4, 1);
        let src = ResidentSource::new(&inner, &ResidencyConfig::new(0).with_tile_rows(8));
        assert!(src.spill_active(), "temp-dir arena must come up");
        let tiles = 40usize.div_ceil(8);
        // three full passes at a different pipeline tile height each
        for pass_tile in [8usize, 8, 8] {
            let mut collect = CollectConsumer::new(40, 4);
            run_pipeline(&src, pass_tile, 2, &mut [&mut collect]);
            assert_eq!(collect.into_matrix().max_abs_diff(&inner.a), 0.0);
        }
        assert_eq!(inner.computes.load(Ordering::SeqCst), tiles, "source must be paid once per tile");
        let st = src.stats();
        assert_eq!(st.computes as usize, tiles);
        assert_eq!(st.spill_hits as usize, 2 * tiles, "later passes read the arena");
        assert_eq!(st.ram_hits, 0, "zero RAM budget keeps nothing hot");
        assert_eq!(st.spilled_bytes, 40 * 4 * 8);
    }

    #[test]
    fn f32_residency_halves_spill_bytes_and_round_trips_bit_exactly() {
        let inner = counting(40, 4, 21);
        let cfg = ResidencyConfig::new(0)
            .with_tile_rows(8)
            .with_precision(Precision::F32);
        let src = ResidentSource::new(&inner, &cfg);
        assert_eq!(src.precision(), Precision::F32);
        let tiles = 40usize.div_ceil(8);
        // the rounded-once tile values every pass must serve bit-exactly
        let narrow = inner.a.demote().promote();
        for _ in 0..2 {
            let mut collect = CollectConsumer::new(40, 4);
            run_pipeline(&src, 8, 2, &mut [&mut collect]);
            assert_eq!(collect.into_matrix().max_abs_diff(&narrow), 0.0);
        }
        let st = src.stats();
        assert_eq!(st.spilled_bytes, 40 * 4 * 4, "f32 spills half the f64 bytes");
        assert_eq!(st.spill_hits as usize, tiles, "pass 2 reads the arena");
        assert_eq!(inner.computes.load(Ordering::SeqCst), tiles, "source paid once per tile");
    }

    #[test]
    fn f32_unaligned_requests_assemble_bit_exactly() {
        let inner = counting(29, 3, 22);
        let cfg = ResidencyConfig::new(29 * 3 * 4 / 2)
            .with_tile_rows(8)
            .with_precision(Precision::F32);
        let src = ResidentSource::new(&inner, &cfg);
        let narrow = inner.a.demote().promote();
        for (r0, r1) in [(0usize, 29usize), (3, 11), (7, 8), (15, 29), (0, 1)] {
            let got = src.tile(r0, r1);
            assert_eq!(
                got.max_abs_diff(&narrow.block(r0, r1, 0, 3)),
                0.0,
                "[{r0},{r1})"
            );
            if let Tile::F32(m) = src.tile_elem(r0, r1, Precision::F32) {
                assert_eq!(m.promote().max_abs_diff(&got), 0.0, "typed path agrees");
            } else {
                panic!("f32-resident layer must serve native f32 tiles");
            }
        }
    }

    #[test]
    fn admission_is_scan_resistant_and_frequency_displaces() {
        let inner = counting(32, 2, 2);
        // grid 8 → 4 tiles of 8*2*8 = 128 bytes; budget holds exactly two
        let src = ResidentSource::new(
            &inner,
            &ResidencyConfig::ram_only(2 * 128).with_tile_rows(8),
        );
        let t = |g: usize| {
            let _ = src.tile(g * 8, g * 8 + 8);
        };
        t(0); // admit {0}
        t(1); // admit {0, 1}
        t(2); // full, uses(2)=1 not > uses(0)=1: rejected, hot set stable
        t(1); // RAM hit
        t(3); // full, uses(3)=1 not > uses(0)=1: rejected
        t(1); // RAM hit
        t(2); // uses(2)=2 > uses(0)=1: displaces the LRU victim 0
        let st = src.stats();
        assert_eq!(st.ram_hits, 2);
        assert_eq!(st.computes, 5, "rejected tiles recompute without spill");
        assert_eq!(st.spill_hits, 0);
        assert_eq!(st.evictions, 1, "only the frequency-justified displacement");
        assert_eq!(inner.computes.load(Ordering::SeqCst), 5);
        // tile 0 was displaced: re-reading it recomputes
        t(0);
        assert_eq!(inner.computes.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn cyclic_scans_hit_in_proportion_to_the_budget() {
        // The planner's hit-rate model (`min(1, ram_budget / panel)`) must
        // be realized by the cache on the workloads this layer serves:
        // repeated full passes. Budget = half the panel → every pass after
        // the first hits RAM on exactly half the tiles (the stable hot
        // prefix) and the spill arena on the rest — never the source.
        let inner = counting(64, 2, 7);
        let tile_bytes = 8 * 2 * 8; // grid 8 → 8 tiles
        let src = ResidentSource::new(
            &inner,
            &ResidencyConfig::new(4 * tile_bytes).with_tile_rows(8),
        );
        for _ in 0..3 {
            let mut collect = CollectConsumer::new(64, 2);
            run_pipeline(&src, 8, 2, &mut [&mut collect]);
            assert_eq!(collect.into_matrix().max_abs_diff(&inner.a), 0.0);
        }
        let st = src.stats();
        assert_eq!(inner.computes.load(Ordering::SeqCst), 8, "source paid once per tile");
        assert_eq!(st.ram_hits, 2 * 4, "passes 2 and 3 hit RAM on the hot half");
        assert_eq!(st.spill_hits, 2 * 4, "…and the arena on the cold half");
        assert_eq!(st.evictions, 0, "cyclic scans never displace the hot set");
    }

    #[test]
    fn ram_only_overflow_recomputes_instead_of_erroring() {
        let inner = counting(20, 3, 3);
        let src = ResidentSource::new(&inner, &ResidencyConfig::ram_only(0).with_tile_rows(5));
        let mut c1 = CollectConsumer::new(20, 3);
        run_pipeline(&src, 5, 2, &mut [&mut c1]);
        let mut c2 = CollectConsumer::new(20, 3);
        run_pipeline(&src, 5, 2, &mut [&mut c2]);
        assert_eq!(c1.into_matrix().max_abs_diff(&c2.into_matrix()), 0.0);
        assert_eq!(inner.computes.load(Ordering::SeqCst), 8, "both passes recompute");
        assert_eq!(src.stats().hits(), 0);
    }

    #[test]
    fn corrupt_record_is_detected_recomputed_and_rewritten() {
        // Flip one payload byte of the first arena record on disk (no
        // fault plan — real bit rot): the next read must detect it via
        // the checksum, recompute exactly that tile, write a fresh
        // record, and keep the arena alive. Results stay bit-exact
        // throughout.
        let inner = counting(40, 4, 30);
        let src = ResidentSource::new(&inner, &ResidencyConfig::new(0).with_tile_rows(8));
        let tiles = 40usize.div_ceil(8);
        let mut c1 = CollectConsumer::new(40, 4);
        run_pipeline(&src, 8, 2, &mut [&mut c1]);
        assert_eq!(c1.into_matrix().max_abs_diff(&inner.a), 0.0);

        let path = src.spill_path().expect("arena live");
        {
            // record 0 starts at offset 0: header, then 8*4 f64s
            use std::io::{Seek, SeekFrom, Write};
            let mut f = File::options().read(true).write(true).open(&path).unwrap();
            f.seek(SeekFrom::Start(RECORD_HEADER_BYTES as u64 + 3)).unwrap();
            f.write_all(&[0xFF]).unwrap();
        }

        let mut c2 = CollectConsumer::new(40, 4);
        run_pipeline(&src, 8, 2, &mut [&mut c2]);
        assert_eq!(c2.into_matrix().max_abs_diff(&inner.a), 0.0, "never wrong bits");
        let st = src.stats();
        assert_eq!(st.corrupt_reads, 1, "exactly the flipped record detected");
        assert_eq!(st.computes as usize, tiles + 1, "only the corrupt tile recomputed");
        assert!(src.spill_active(), "one bad record must not drop the arena");

        // pass 3: the rewritten record serves cleanly from disk
        let mut c3 = CollectConsumer::new(40, 4);
        run_pipeline(&src, 8, 2, &mut [&mut c3]);
        assert_eq!(c3.into_matrix().max_abs_diff(&inner.a), 0.0);
        let st = src.stats();
        assert_eq!(st.corrupt_reads, 1, "no further corruption seen");
        assert_eq!(st.computes as usize, tiles + 1);
        assert_eq!(inner.computes.load(Ordering::SeqCst), tiles + 1);
    }

    #[test]
    fn arena_file_is_removed_on_drop() {
        let inner = counting(16, 2, 4);
        let path = {
            let src = ResidentSource::new(&inner, &ResidencyConfig::new(u64::MAX).with_tile_rows(4));
            let _ = src.tile(0, 16);
            let p = src.spill_path().expect("arena live");
            assert!(p.exists(), "arena file must exist while the source lives");
            p
        };
        assert!(!path.exists(), "arena file must be unlinked on drop");
    }

    #[test]
    fn arena_file_is_removed_even_when_a_consumer_panics() {
        let inner = counting(24, 2, 5);
        let path = std::sync::Mutex::new(None::<PathBuf>);
        struct Bomb;
        impl TileConsumer for Bomb {
            fn consume(&mut self, r0: usize, _tile: &Matrix) {
                if r0 >= 8 {
                    panic!("consumer bomb");
                }
            }
        }
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let src = ResidentSource::new(&inner, &ResidencyConfig::new(0).with_tile_rows(4));
            *path.lock().unwrap() = src.spill_path();
            run_pipeline(&src, 4, 1, &mut [&mut Bomb]);
        }));
        assert!(result.is_err(), "panic must propagate");
        let p = path.lock().unwrap().take().expect("arena was live");
        assert!(!p.exists(), "guard must unlink the arena during unwind");
    }

    #[test]
    fn matches_plain_source_through_the_pipeline() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(37, 5, &mut rng);
        let plain = MatrixSource::new(&a);
        for tile_rows in [1usize, 7, 37, 64] {
            let cfg = ResidencyConfig::new(512).with_tile_rows(tile_rows.min(37));
            let src = ResidentSource::new(&plain, &cfg);
            for pass in 0..2 {
                let mut collect = CollectConsumer::new(37, 5);
                run_pipeline(&src, tile_rows, 2, &mut [&mut collect]);
                assert_eq!(
                    collect.into_matrix().max_abs_diff(&a),
                    0.0,
                    "tile={tile_rows} pass={pass}"
                );
            }
        }
    }

    #[test]
    fn empty_source_is_a_passthrough() {
        let a = Matrix::zeros(0, 3);
        let plain = MatrixSource::new(&a);
        let src = ResidentSource::new(&plain, &ResidencyConfig::new(0));
        assert_eq!(src.rows(), 0);
        assert!(!src.spill_active(), "no arena for an empty source");
        run_pipeline(&src, 4, 2, &mut []);
    }
}
