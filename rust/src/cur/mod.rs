//! CUR matrix decomposition (paper §5): `A ≈ C U R` with
//!
//! - [`cur_optimal`] — `U* = C† A R†` (eq. 8, cost O(mn·min{c,r})),
//! - [`cur_drineas08`] — `U = (P_R^T A P_C)†` (the cheap 2008 baseline the
//!   paper's Fig. 2(c) shows is poor),
//! - fast CUR — `Ũ = (S_C^T C)† (S_C^T A S_R) (R S_R)†` (eq. 9,
//!   Theorem 9) with uniform or leverage-score `S_C`, `S_R` — served by
//!   [`exec::cur_fast`](crate::exec::cur_fast) under any
//!   [`ExecPolicy`](crate::exec::ExecPolicy),
//! - [`adaptive_sample`] / [`uniform_adaptive2`] — residual-based column
//!   selection (Wang et al. 2016) used to build better `C` (paper Fig. 4
//!   and Theorem 8's near-optimal selection).

pub mod sparse_cur;

use crate::linalg::{pinv, Matrix};
use crate::obs::{self, Stage};
use crate::sketch::{self, SketchKind};
use crate::stream::{
    run_pipeline_prec, ColSubsetCollect, MatrixSource, ResidencyConfig, ResidencyStats,
    ResidentSource, RowGather, StreamConfig,
};
use crate::util::{Rng, Stopwatch};

/// A CUR decomposition `A ≈ C U R`.
#[derive(Debug, Clone)]
pub struct CurDecomp {
    pub c: Matrix, // m x c
    pub u: Matrix, // c x r
    pub r: Matrix, // r x n
    pub method: String,
    pub build_secs: f64,
    /// Entries of `A` read to *compute U* (C and R excluded — all methods
    /// share them).
    pub entries_for_u: u64,
}

impl CurDecomp {
    pub fn materialize(&self) -> Matrix {
        self.c.matmul(&self.u).matmul(&self.r)
    }

    pub fn rel_fro_error(&self, a: &Matrix) -> f64 {
        a.sub(&self.materialize()).fro_norm_sq() / a.fro_norm_sq()
    }
}

/// Uniformly sample `count` distinct indices from `[0, n)`, sorted.
pub fn select_uniform(n: usize, count: usize, rng: &mut Rng) -> Vec<usize> {
    let mut idx = rng.sample_without_replacement(n, count.min(n));
    idx.sort_unstable();
    idx
}

/// Optimal U: `U* = C† A R†` — O(mn·min{c,r}).
pub fn cur_optimal(a: &Matrix, col_idx: &[usize], row_idx: &[usize]) -> CurDecomp {
    let sw = Stopwatch::start();
    let c = a.select_cols(col_idx);
    let r = a.select_rows(row_idx);
    let cp = pinv(&c); // c x m
    let rp = pinv(&r); // n x r
    let u = cp.matmul(a).matmul(&rp);
    CurDecomp {
        c,
        u,
        r,
        method: "optimal".into(),
        build_secs: sw.secs(),
        entries_for_u: (a.rows() * a.cols()) as u64,
    }
}

/// Drineas et al. (2008): `U = (P_R^T A P_C)† = (A[rows, cols])†` — the
/// degenerate fast model with `S_C = P_R`, `S_R = P_C`.
pub fn cur_drineas08(a: &Matrix, col_idx: &[usize], row_idx: &[usize]) -> CurDecomp {
    let sw = Stopwatch::start();
    let c = a.select_cols(col_idx);
    let r = a.select_rows(row_idx);
    let w = a.select_rows(row_idx).select_cols(col_idx); // r x c
    let u = pinv(&w); // c x r
    CurDecomp {
        c,
        u,
        r,
        method: "drineas08".into(),
        build_secs: sw.secs(),
        entries_for_u: (row_idx.len() * col_idx.len()) as u64,
    }
}

/// How CUR's leverage configs compute the scores of the sampling basis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CurScoreBasis {
    /// `O(c²)` Gram-based scores (the streamed leverage estimator —
    /// default). Squares the basis's condition number: directions with
    /// relative singular value between `√ε` and `ε` score at the Gram's
    /// rounding floor.
    Gram,
    /// SVD of the resident basis (the historical behavior): `O(m·c)`
    /// scratch, robust to ill-conditioned `C`/`R`.
    ExactSvd,
}

/// Configuration for the fast CUR U matrix (eq. 9).
#[derive(Debug, Clone, Copy)]
pub struct FastCurConfig {
    pub s_c: usize,
    pub s_r: usize,
    /// Uniform or Leverage (w.r.t. row leverage of C / column leverage of R).
    pub kind: SketchKind,
    /// Force the selected rows to include `row_idx` and columns to include
    /// `col_idx` (the CUR analogue of Corollary 5; improves accuracy).
    pub force_overlap: bool,
    /// Score estimator for `SketchKind::Leverage` (ignored otherwise).
    pub score_basis: CurScoreBasis,
}

impl FastCurConfig {
    pub fn uniform(s_c: usize, s_r: usize) -> Self {
        FastCurConfig {
            s_c,
            s_r,
            kind: SketchKind::Uniform,
            force_overlap: true,
            score_basis: CurScoreBasis::Gram,
        }
    }

    pub fn leverage(s_c: usize, s_r: usize) -> Self {
        FastCurConfig {
            s_c,
            s_r,
            kind: SketchKind::Leverage { scaled: false },
            force_overlap: true,
            score_basis: CurScoreBasis::Gram,
        }
    }

    /// Leverage with SVD-based scores (the conditioning-robust reference).
    pub fn leverage_svd(s_c: usize, s_r: usize) -> Self {
        FastCurConfig { score_basis: CurScoreBasis::ExactSvd, ..Self::leverage(s_c, s_r) }
    }
}

/// Unified fast-CUR builder: `Ũ = (S_C^T C)† (S_C^T A S_R) (R S_R)†`,
/// column-selection sketches only (the linear-time regime the paper
/// recommends; projection sketches would need all of A). The
/// non-deprecated entry point is
/// [`exec::cur_fast`](crate::exec::cur_fast).
///
/// - `stream_cfg = None` — the materialized path: direct gathers from the
///   resident `A` (the historical `cur_fast`).
/// - `Some(cfg)` — `A` flows by in row tiles and the consumers pick out
///   `C = A[:, P_C]`, `R = A[P_R, :]` and (for uniform sketches, whose
///   indices don't depend on `C`/`R`) the `S_C x S_R` core in the same
///   single pass; leverage sketches draw their indices after the `C`/`R`
///   pass and gather the core from the resident matrix. Peak extra memory
///   beyond the `C`/`R`/`U` outputs is `O(tile_rows · n + s_c · s_r)`.
/// - `residency = Some(rc)` — every tile additionally writes through the
///   LRU + spill arena, and the leverage family's core gather re-streams
///   through the residency layer instead of indexing the resident `A`, so
///   a disk-backed `A` is read exactly once however many passes run.
///
/// The rng sequence is shared by all paths (uniform indices are drawn up
/// front; leverage draws happen after the `C`/`R` pass in every path), so
/// results are **bit-identical** across policies.
pub(crate) fn run_cur_fast(
    a: &Matrix,
    col_idx: &[usize],
    row_idx: &[usize],
    cfg: FastCurConfig,
    stream_cfg: Option<StreamConfig>,
    residency: Option<&ResidencyConfig>,
    rng: &mut Rng,
) -> (CurDecomp, Option<ResidencyStats>) {
    let sw = Stopwatch::start();
    let (m, n) = (a.rows(), a.cols());
    let forced_rows: &[usize] = if cfg.force_overlap { row_idx } else { &[] };
    let forced_cols: &[usize] = if cfg.force_overlap { col_idx } else { &[] };
    assert!(
        cfg.kind.is_column_selection(),
        "fast CUR supports column-selection sketches, not {}",
        cfg.kind.name()
    );

    let src = MatrixSource::new(a);
    let resident = residency.map(|rc| ResidentSource::new(&src, rc));
    // The pipeline paths: residency implies streaming (grid height from
    // the stream config, which the exec layer aligns with the grid).
    let piped = match (&resident, stream_cfg) {
        (Some(_), cfg) => Some(cfg.unwrap_or_default()),
        (None, cfg) => cfg,
    };

    let (c, r, sc_idx, sr_idx, core) = match piped {
        None => {
            // Materialized: direct gathers from the resident A.
            let c = a.select_cols(col_idx);
            let r = a.select_rows(row_idx);
            let sc_idx =
                build_indices(&c, cfg.kind, cfg.score_basis, cfg.s_c, m, forced_rows, rng);
            let rt = r.transpose();
            let sr_idx =
                build_indices(&rt, cfg.kind, cfg.score_basis, cfg.s_r, n, forced_cols, rng);
            let core = a.select_rows(&sc_idx).select_cols(&sr_idx); // s_c x s_r
            (c, r, sc_idx, sr_idx, core)
        }
        Some(stream_cfg) => {
            let t = stream_cfg.effective_tile_rows(m);
            let source: &dyn crate::stream::TileSource = match &resident {
                Some(res) => res,
                None => &src,
            };
            let mut c_collect = ColSubsetCollect::new(m, col_idx.to_vec());
            let mut r_gather = RowGather::new(row_idx.to_vec(), n);
            match cfg.kind {
                SketchKind::Uniform => {
                    // Indices first (the basis is ignored for uniform
                    // sampling), then one pass gathers C, R and the core
                    // together.
                    let dummy = Matrix::zeros(0, 0);
                    let sc_idx = build_indices(
                        &dummy, cfg.kind, cfg.score_basis, cfg.s_c, m, forced_rows, rng,
                    );
                    let sr_idx = build_indices(
                        &dummy, cfg.kind, cfg.score_basis, cfg.s_r, n, forced_cols, rng,
                    );
                    let mut core_gather = RowGather::with_cols(sc_idx.clone(), sr_idx.clone());
                    run_pipeline_prec(
                        source,
                        t,
                        stream_cfg.queue_depth,
                        stream_cfg.precision,
                        &mut [&mut c_collect, &mut r_gather, &mut core_gather],
                    );
                    (
                        c_collect.into_matrix(),
                        r_gather.into_matrix(),
                        sc_idx,
                        sr_idx,
                        core_gather.into_matrix(),
                    )
                }
                _ => {
                    // Leverage. Pass 1: C and R. Then draw the leverage
                    // indices exactly as the materialized path does. The
                    // s_c x s_r core cannot be folded in pass 1 (the
                    // indices don't exist yet): without residency it is a
                    // direct gather from the resident `a` (re-streaming
                    // all m rows to keep s_c of them would be pure
                    // overhead); with residency pass 2 reloads tiles from
                    // the LRU/arena — the backing store is never consulted
                    // again.
                    run_pipeline_prec(
                        source,
                        t,
                        stream_cfg.queue_depth,
                        stream_cfg.precision,
                        &mut [&mut c_collect, &mut r_gather],
                    );
                    let c = c_collect.into_matrix();
                    let r = r_gather.into_matrix();
                    let sc_idx = build_indices(
                        &c, cfg.kind, cfg.score_basis, cfg.s_c, m, forced_rows, rng,
                    );
                    let rt = r.transpose();
                    let sr_idx = build_indices(
                        &rt, cfg.kind, cfg.score_basis, cfg.s_r, n, forced_cols, rng,
                    );
                    let core = match &resident {
                        Some(res) => {
                            let mut core_gather =
                                RowGather::with_cols(sc_idx.clone(), sr_idx.clone());
                            run_pipeline_prec(
                                res,
                                t,
                                stream_cfg.queue_depth,
                                stream_cfg.precision,
                                &mut [&mut core_gather],
                            );
                            core_gather.into_matrix()
                        }
                        None => Matrix::from_fn(sc_idx.len(), sr_idx.len(), |i, j| {
                            a[(sc_idx[i], sr_idx[j])]
                        }),
                    };
                    (c, r, sc_idx, sr_idx, core)
                }
            }
        }
    };

    let stc = c.select_rows(&sc_idx); // s_c x c
    let rsr = r.select_cols(&sr_idx); // r x s_r
    let u = {
        let _s = obs::span(Stage::SolveSvd);
        pinv(&stc).matmul(&core).matmul(&pinv(&rsr))
    };
    let decomp = CurDecomp {
        c,
        u,
        r,
        method: format!("fast[{}]", cfg.kind.name()),
        build_secs: sw.secs(),
        entries_for_u: (sc_idx.len() * sr_idx.len()) as u64,
    };
    let stats = resident.map(|res| res.stats());
    (decomp, stats)
}

// ---------------------------------------------------------------------------
// Deprecated per-policy shims over the unified builder (`exec::cur_fast`
// is the policy-carrying surface).
// ---------------------------------------------------------------------------

/// Fast CUR on the materialized path.
#[deprecated(note = "use `exec::cur_fast` with `ExecPolicy::Materialized`")]
pub fn cur_fast(
    a: &Matrix,
    col_idx: &[usize],
    row_idx: &[usize],
    cfg: FastCurConfig,
    rng: &mut Rng,
) -> CurDecomp {
    run_cur_fast(a, col_idx, row_idx, cfg, None, None, rng).0
}

/// Fast CUR through the tile pipeline.
#[deprecated(note = "use `exec::cur_fast` with `ExecPolicy::Streamed`")]
pub fn cur_fast_streamed(
    a: &Matrix,
    col_idx: &[usize],
    row_idx: &[usize],
    cfg: FastCurConfig,
    stream_cfg: StreamConfig,
    rng: &mut Rng,
) -> CurDecomp {
    run_cur_fast(a, col_idx, row_idx, cfg, Some(stream_cfg), None, rng).0
}

/// Fast CUR through the tile residency layer.
#[deprecated(note = "use `exec::cur_fast` with `ExecPolicy::Resident`")]
pub fn cur_fast_streamed_resident(
    a: &Matrix,
    col_idx: &[usize],
    row_idx: &[usize],
    cfg: FastCurConfig,
    stream_cfg: StreamConfig,
    residency: &ResidencyConfig,
    rng: &mut Rng,
) -> (CurDecomp, ResidencyStats) {
    let (d, s) = run_cur_fast(a, col_idx, row_idx, cfg, Some(stream_cfg), Some(residency), rng);
    (d, s.expect("residency stats"))
}

/// Sample `s` row indices of `basis` (uniform or by row leverage scores),
/// unioned with `forced`.
pub(crate) fn build_indices(
    basis: &Matrix,
    kind: SketchKind,
    score_basis: CurScoreBasis,
    s: usize,
    n: usize,
    forced: &[usize],
    rng: &mut Rng,
) -> Vec<usize> {
    let extra = s.saturating_sub(forced.len()).max(1);
    let mut idx: Vec<usize> = match kind {
        SketchKind::Uniform => rng.sample_without_replacement(n, extra.min(n)),
        SketchKind::Leverage { .. } => {
            // Default: Gram-based scores (the streamed leverage
            // estimator) — O(c²) whitening state instead of an SVD of the
            // full basis, same scores in exact arithmetic, and shared by
            // every execution policy so materialized and streamed builds
            // stay bit-identical. ExactSvd is the conditioning-robust
            // opt-out.
            let scores = match score_basis {
                CurScoreBasis::Gram => {
                    sketch::approx_leverage_from_gram(&basis.gram_tn()).scores(basis)
                }
                CurScoreBasis::ExactSvd => sketch::leverage_scores(basis),
            };
            let rank: f64 = scores.iter().sum();
            let mut out = Vec::new();
            for (i, &l) in scores.iter().enumerate() {
                let p = if rank > 0.0 { (extra as f64 * l / rank).min(1.0) } else { extra as f64 / n as f64 };
                if rng.bernoulli(p) {
                    out.push(i);
                }
            }
            if out.is_empty() {
                out.push(rng.usize_below(n));
            }
            out
        }
        other => panic!("fast CUR supports column-selection sketches, not {}", other.name()),
    };
    idx.extend_from_slice(forced);
    idx.sort_unstable();
    idx.dedup();
    idx
}

/// Adaptive sampling (Wang & Zhang 2013): sample `count` extra column
/// indices with probability proportional to the squared column norms of the
/// residual `A - C C† A`. Requires the full matrix.
pub fn adaptive_sample(a: &Matrix, current_cols: &[usize], count: usize, rng: &mut Rng) -> Vec<usize> {
    let c = a.select_cols(current_cols);
    let cp = pinv(&c);
    let proj = c.matmul(&cp.matmul(a)); // C C† A
    // Residual column norms accumulated row-major in one streaming pass
    // (no column-strided reads, no residual matrix materialized).
    let mut weights = vec![0.0f64; a.cols()];
    for i in 0..a.rows() {
        let (ar, pr) = (a.row(i), proj.row(i));
        for (w, (&av, &pv)) in weights.iter_mut().zip(ar.iter().zip(pr)) {
            let r = av - pv;
            *w += r * r;
        }
    }
    let mut chosen = Vec::with_capacity(count);
    let mut w = weights;
    for &cidx in current_cols {
        w[cidx] = 0.0; // don't re-pick existing columns
    }
    for _ in 0..count {
        let j = rng.weighted_index(&w);
        chosen.push(j);
        w[j] = 0.0;
    }
    chosen.sort_unstable();
    chosen.dedup();
    chosen
}

/// The uniform+adaptive² column-selection of Wang et al. (2016): c/3
/// uniform, then two adaptive rounds of c/3 against the growing residual.
pub fn uniform_adaptive2(a: &Matrix, c: usize, rng: &mut Rng) -> Vec<usize> {
    let n = a.cols();
    let c1 = (c / 3).max(1);
    let c3 = c.saturating_sub(2 * c1).max(1);
    let mut idx = select_uniform(n, c1, rng);
    let extra1 = adaptive_sample(a, &idx, c1, rng);
    idx.extend(extra1);
    idx.sort_unstable();
    idx.dedup();
    let extra2 = adaptive_sample(a, &idx, c3, rng);
    idx.extend(extra2);
    idx.sort_unstable();
    idx.dedup();
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{self, ExecPolicy};
    use crate::testkit::gen;

    fn decaying_matrix(m: usize, n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let r = m.min(n);
        let u = crate::linalg::qr::qr_thin(&Matrix::randn(m, r, &mut rng)).q;
        let v = crate::linalg::qr::qr_thin(&Matrix::randn(n, r, &mut rng)).q;
        let ud = Matrix::from_fn(m, r, |i, j| u[(i, j)] / ((j + 1) as f64).powi(2));
        ud.matmul_tr(&v)
    }

    fn fast_m(
        a: &Matrix,
        cols: &[usize],
        rows: &[usize],
        cfg: FastCurConfig,
        rng: &mut Rng,
    ) -> CurDecomp {
        exec::cur_fast(a, cols, rows, cfg, &ExecPolicy::Materialized, rng).result
    }

    #[test]
    fn optimal_is_best_for_fixed_c_r() {
        let a = decaying_matrix(40, 30, 0);
        let mut rng = Rng::new(1);
        let cols = select_uniform(30, 6, &mut rng);
        let rows = select_uniform(40, 6, &mut rng);
        let opt = cur_optimal(&a, &cols, &rows);
        let dri = cur_drineas08(&a, &cols, &rows);
        let fast = fast_m(&a, &cols, &rows, FastCurConfig::uniform(24, 24), &mut rng);
        let (e_opt, e_dri, e_fast) =
            (opt.rel_fro_error(&a), dri.rel_fro_error(&a), fast.rel_fro_error(&a));
        assert!(e_opt <= e_fast + 1e-9, "optimal {e_opt} vs fast {e_fast}");
        assert!(e_opt <= e_dri + 1e-9);
        // Fig-2 shape: fast with s=4r is close to optimal, drineas08 is worse
        assert!(e_fast <= e_dri + 1e-9, "fast {e_fast} should beat drineas08 {e_dri}");
    }

    #[test]
    fn fast_cur_entry_count() {
        let a = decaying_matrix(50, 45, 2);
        let mut rng = Rng::new(3);
        let cols = select_uniform(45, 5, &mut rng);
        let rows = select_uniform(50, 5, &mut rng);
        let f = fast_m(&a, &cols, &rows, FastCurConfig::uniform(20, 20), &mut rng);
        assert!(f.entries_for_u <= 25 * 25);
        let o = cur_optimal(&a, &cols, &rows);
        assert_eq!(o.entries_for_u, 50 * 45);
    }

    #[test]
    fn exact_recovery_low_rank() {
        // rank(A)=3, c=r=5 ⇒ all methods with enough sketch recover exactly
        let mut rng = Rng::new(4);
        let a = gen::low_rank(&mut rng, 30, 25, 3);
        let cols = select_uniform(25, 5, &mut rng);
        let rows = select_uniform(30, 5, &mut rng);
        let opt = cur_optimal(&a, &cols, &rows);
        assert!(opt.rel_fro_error(&a) < 1e-10);
        let fast = fast_m(&a, &cols, &rows, FastCurConfig::uniform(15, 15), &mut rng);
        assert!(fast.rel_fro_error(&a) < 1e-9, "err={}", fast.rel_fro_error(&a));
    }

    #[test]
    fn leverage_fast_cur_works() {
        let a = decaying_matrix(35, 30, 5);
        let mut rng = Rng::new(6);
        let cols = select_uniform(30, 5, &mut rng);
        let rows = select_uniform(35, 5, &mut rng);
        let f = fast_m(&a, &cols, &rows, FastCurConfig::leverage(20, 20), &mut rng);
        let e = f.rel_fro_error(&a);
        let e_opt = cur_optimal(&a, &cols, &rows).rel_fro_error(&a);
        assert!(e <= 3.0 * e_opt + 1e-6, "leverage fast {e} vs opt {e_opt}");
    }

    #[test]
    fn streamed_cur_is_bit_identical_to_materialized() {
        let a = decaying_matrix(41, 33, 12); // awkward sizes vs tile heights
        for tile in [1usize, 7, 16, 41] {
            for cfg in [
                FastCurConfig::uniform(18, 18),
                FastCurConfig::leverage(18, 18),
                FastCurConfig::leverage_svd(18, 18),
            ] {
                let mut r1 = Rng::new(77);
                let mut r2 = Rng::new(77);
                let cols = select_uniform(33, 5, &mut r1);
                let rows = select_uniform(41, 5, &mut r1);
                let cols2 = select_uniform(33, 5, &mut r2);
                let rows2 = select_uniform(41, 5, &mut r2);
                assert_eq!(cols, cols2);
                let mat = fast_m(&a, &cols, &rows, cfg, &mut r1);
                let st = exec::cur_fast(&a, &cols2, &rows2, cfg, &ExecPolicy::streamed(tile), &mut r2)
                    .result;
                assert_eq!(mat.c.max_abs_diff(&st.c), 0.0, "C tile={tile}");
                assert_eq!(mat.r.max_abs_diff(&st.r), 0.0, "R tile={tile}");
                assert_eq!(mat.u.max_abs_diff(&st.u), 0.0, "{} U tile={tile}", mat.method);
                assert_eq!(mat.entries_for_u, st.entries_for_u);
            }
        }
    }

    #[test]
    fn resident_cur_is_bit_identical_and_reloads_pass_two() {
        let a = decaying_matrix(41, 33, 12);
        for (budget, tile) in [(0u64, 7usize), (u64::MAX, 7), (0, 16)] {
            for cfg in [FastCurConfig::uniform(18, 18), FastCurConfig::leverage(18, 18)] {
                let mut r1 = Rng::new(77);
                let mut r2 = Rng::new(77);
                let cols = select_uniform(33, 5, &mut r1);
                let rows = select_uniform(41, 5, &mut r1);
                let cols2 = select_uniform(33, 5, &mut r2);
                let rows2 = select_uniform(41, 5, &mut r2);
                let mat = fast_m(&a, &cols, &rows, cfg, &mut r1);
                let policy = ExecPolicy::resident(budget).with_tile_rows(tile);
                let rep = exec::cur_fast(&a, &cols2, &rows2, cfg, &policy, &mut r2);
                let (st, stats) = (rep.result, rep.meta.residency.expect("stats"));
                assert_eq!(mat.c.max_abs_diff(&st.c), 0.0, "C tile={tile}");
                assert_eq!(mat.r.max_abs_diff(&st.r), 0.0, "R tile={tile}");
                assert_eq!(mat.u.max_abs_diff(&st.u), 0.0, "{} U tile={tile}", mat.method);
                let tiles = 41usize.div_ceil(tile) as u64;
                assert_eq!(stats.computes, tiles, "source read once per tile");
                if matches!(cfg.kind, SketchKind::Leverage { .. }) {
                    // pass 2 (the core gather) must come back from residency
                    assert_eq!(stats.hits(), tiles, "budget={budget} tile={tile}");
                    if budget == 0 {
                        assert_eq!(stats.spill_hits, tiles);
                    }
                }
            }
        }
    }

    #[test]
    fn adaptive_improves_over_uniform() {
        // Adaptive column selection should (on average) beat uniform for C.
        let a = decaying_matrix(60, 50, 7);
        let mut e_uni = 0.0;
        let mut e_ada = 0.0;
        for t in 0..5 {
            let mut rng = Rng::new(100 + t);
            let cols_u = select_uniform(50, 9, &mut rng);
            let rows = select_uniform(60, 9, &mut rng);
            e_uni += cur_optimal(&a, &cols_u, &rows).rel_fro_error(&a);
            let cols_a = uniform_adaptive2(&a, 9, &mut rng);
            e_ada += cur_optimal(&a, &cols_a, &rows).rel_fro_error(&a);
        }
        assert!(
            e_ada <= e_uni * 1.1,
            "adaptive ({e_ada}) should be ~at least as good as uniform ({e_uni})"
        );
    }

    #[test]
    fn adaptive_sample_avoids_existing() {
        let a = decaying_matrix(20, 15, 8);
        let mut rng = Rng::new(9);
        let current = vec![0usize, 1, 2];
        let extra = adaptive_sample(&a, &current, 4, &mut rng);
        assert!(extra.iter().all(|e| !current.contains(e)));
    }

    #[test]
    #[should_panic(expected = "column-selection")]
    fn fast_cur_rejects_projection_sketch() {
        let a = decaying_matrix(10, 10, 10);
        let mut rng = Rng::new(11);
        let cfg = FastCurConfig {
            s_c: 5,
            s_r: 5,
            kind: SketchKind::Gaussian,
            force_overlap: false,
            score_basis: CurScoreBasis::Gram,
        };
        fast_m(&a, &[0, 1], &[0, 1], cfg, &mut rng);
    }
}
