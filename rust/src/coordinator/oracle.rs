//! Kernel oracles: blockwise access to `K` without materializing it.
//!
//! The paper's central accounting (Figure 1, Table 3 right column) is *how
//! many entries of K each model observes*. Every oracle counts the entries
//! it serves, so tests and benches can verify e.g. that the fast model sees
//! `nc + (s-c)^2` entries while the prototype model sees `n^2`.

use super::engine::KernelEngine;
use crate::linalg::{Matrix, MatrixF32, Precision, Tile};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One-entry memo of the gathered landmark rows `x[cols, :]`: a streamed
/// build calls `row_block` once per tile with the same `cols`, and without
/// this the `c x d` gather would be recomputed n/tile_rows times (at
/// tile_rows=1 that copy rivals the kernel evaluation itself).
struct LandmarkCache {
    key: Vec<usize>,
    rows: Arc<Matrix>,
}

impl LandmarkCache {
    fn lookup(slot: &Mutex<Option<LandmarkCache>>, x: &Matrix, cols: &[usize]) -> Arc<Matrix> {
        let mut guard = slot.lock().unwrap();
        if let Some(c) = guard.as_ref() {
            if c.key == cols {
                return Arc::clone(&c.rows);
            }
        }
        let rows = Arc::new(x.select_rows(cols));
        *guard = Some(LandmarkCache { key: cols.to_vec(), rows: Arc::clone(&rows) });
        rows
    }
}

/// Blockwise access to a symmetric kernel matrix.
pub trait KernelOracle: Sync {
    /// Matrix dimension n.
    fn n(&self) -> usize;

    /// The `K[rows, cols]` block.
    fn block(&self, rows: &[usize], cols: &[usize]) -> Matrix;

    /// Contiguous row-range fast path: `K[r0..r1, cols]`. The default
    /// builds a row-index `Vec`; implementations override to avoid the
    /// allocation — this is the call the streaming tiles sit on, so it
    /// runs once per tile, not once per build.
    fn row_block(&self, r0: usize, r1: usize, cols: &[usize]) -> Matrix {
        let rows: Vec<usize> = (r0..r1).collect();
        self.block(&rows, cols)
    }

    /// Contiguous full-width rows `K[r0..r1, :]` (the prototype / projection
    /// sketch tile). Default pays one `0..n` index `Vec`; implementations
    /// override to serve the rows directly.
    fn full_rows(&self, r0: usize, r1: usize) -> Matrix {
        let all: Vec<usize> = (0..self.n()).collect();
        self.row_block(r0, r1, &all)
    }

    /// [`row_block`](Self::row_block) at f32 tile width. The default
    /// computes in f64 and demotes (always correct); the analytic oracles
    /// override it with a native narrow-tile kernel evaluation so an f32
    /// run actually buys the bandwidth it asks for.
    fn row_block_f32(&self, r0: usize, r1: usize, cols: &[usize]) -> MatrixF32 {
        self.row_block(r0, r1, cols).demote()
    }

    /// [`full_rows`](Self::full_rows) at f32 tile width (default: demote).
    fn full_rows_f32(&self, r0: usize, r1: usize) -> MatrixF32 {
        self.full_rows(r0, r1).demote()
    }

    /// Width-dispatched column block: the typed-tile entry the streaming
    /// sources sit on.
    fn row_block_elem(&self, r0: usize, r1: usize, cols: &[usize], prec: Precision) -> Tile {
        match prec {
            Precision::F64 => Tile::F64(self.row_block(r0, r1, cols)),
            Precision::F32 => Tile::F32(self.row_block_f32(r0, r1, cols)),
        }
    }

    /// Width-dispatched full-row block.
    fn full_rows_elem(&self, r0: usize, r1: usize, prec: Precision) -> Tile {
        match prec {
            Precision::F64 => Tile::F64(self.full_rows(r0, r1)),
            Precision::F32 => Tile::F32(self.full_rows_f32(r0, r1)),
        }
    }

    /// Entries served so far (for the #entries accounting).
    fn entries_observed(&self) -> u64;

    /// Reset the entry counter.
    fn reset_entries(&self);

    /// Convenience: full columns `K[:, cols]` (the sketch `C` for a column
    /// selection matrix `P`).
    fn columns(&self, cols: &[usize]) -> Matrix {
        self.row_block(0, self.n(), cols)
    }

    /// Convenience: the full matrix (the prototype model's requirement).
    fn full(&self) -> Matrix {
        self.full_rows(0, self.n())
    }
}

/// Oracle over an explicit dense matrix (tests, small baselines, and the
/// CUR image experiment).
pub struct DenseOracle {
    k: Matrix,
    entries: AtomicU64,
}

impl DenseOracle {
    pub fn new(k: Matrix) -> Self {
        assert_eq!(k.rows(), k.cols(), "kernel oracle needs a square matrix");
        DenseOracle { k, entries: AtomicU64::new(0) }
    }

    pub fn inner(&self) -> &Matrix {
        &self.k
    }
}

impl KernelOracle for DenseOracle {
    fn n(&self) -> usize {
        self.k.rows()
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Matrix {
        self.entries
            .fetch_add((rows.len() * cols.len()) as u64, Ordering::Relaxed);
        let mut out = Matrix::zeros(rows.len(), cols.len());
        for (i, &r) in rows.iter().enumerate() {
            let src = self.k.row(r);
            let dst = out.row_mut(i);
            for (j, &c) in cols.iter().enumerate() {
                dst[j] = src[c];
            }
        }
        out
    }

    fn row_block(&self, r0: usize, r1: usize, cols: &[usize]) -> Matrix {
        self.entries
            .fetch_add(((r1 - r0) * cols.len()) as u64, Ordering::Relaxed);
        let mut out = Matrix::zeros(r1 - r0, cols.len());
        for i in r0..r1 {
            let src = self.k.row(i);
            let dst = out.row_mut(i - r0);
            for (j, &c) in cols.iter().enumerate() {
                dst[j] = src[c];
            }
        }
        out
    }

    fn full_rows(&self, r0: usize, r1: usize) -> Matrix {
        self.entries
            .fetch_add(((r1 - r0) * self.k.cols()) as u64, Ordering::Relaxed);
        self.k.block(r0, r1, 0, self.k.cols())
    }

    fn entries_observed(&self) -> u64 {
        self.entries.load(Ordering::Relaxed)
    }

    fn reset_entries(&self) {
        self.entries.store(0, Ordering::Relaxed);
    }
}

/// RBF kernel oracle over a data matrix: `K_ij = exp(-gamma ||x_i - x_j||^2)`.
/// Blocks are computed on demand by the [`KernelEngine`] (PJRT-backed when
/// artifacts are loaded, pure-rust otherwise) — this is the path that keeps
/// the fast model's kernel evaluations at `nc + (s-c)^2` instead of `n^2`.
pub struct RbfOracle {
    /// n x d data matrix (rows are points).
    x: Arc<Matrix>,
    pub gamma: f64,
    engine: Arc<KernelEngine>,
    entries: AtomicU64,
    landmarks: Mutex<Option<LandmarkCache>>,
}

impl RbfOracle {
    pub fn new(x: Arc<Matrix>, gamma: f64, engine: Arc<KernelEngine>) -> Self {
        RbfOracle { x, gamma, engine, entries: AtomicU64::new(0), landmarks: Mutex::new(None) }
    }

    /// Build with the pure-rust engine (no PJRT).
    pub fn cpu(x: Arc<Matrix>, gamma: f64) -> Self {
        Self::new(x, gamma, Arc::new(KernelEngine::cpu()))
    }

    pub fn data(&self) -> &Matrix {
        &self.x
    }

    /// Cross-kernel block against external points (test-time k(x) columns).
    pub fn cross(&self, other: &Matrix) -> Matrix {
        self.engine.rbf_cross(&self.x, other, self.gamma)
    }
}

impl KernelOracle for RbfOracle {
    fn n(&self) -> usize {
        self.x.rows()
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Matrix {
        self.entries
            .fetch_add((rows.len() * cols.len()) as u64, Ordering::Relaxed);
        let xr = self.x.select_rows(rows);
        let xc = self.x.select_rows(cols);
        self.engine.rbf_cross(&xr, &xc, self.gamma)
    }

    fn row_block(&self, r0: usize, r1: usize, cols: &[usize]) -> Matrix {
        self.entries
            .fetch_add(((r1 - r0) * cols.len()) as u64, Ordering::Relaxed);
        let xr = self.x.block(r0, r1, 0, self.x.cols());
        let xc = LandmarkCache::lookup(&self.landmarks, &self.x, cols);
        self.engine.rbf_cross(&xr, &xc, self.gamma)
    }

    fn full_rows(&self, r0: usize, r1: usize) -> Matrix {
        self.entries
            .fetch_add(((r1 - r0) * self.n()) as u64, Ordering::Relaxed);
        if r0 == 0 && r1 == self.n() {
            // same-reference dispatch takes the symmetric Gram path
            return self.engine.rbf_cross(&self.x, &self.x, self.gamma);
        }
        let xr = self.x.block(r0, r1, 0, self.x.cols());
        self.engine.rbf_cross(&xr, &self.x, self.gamma)
    }

    fn row_block_f32(&self, r0: usize, r1: usize, cols: &[usize]) -> MatrixF32 {
        self.entries
            .fetch_add(((r1 - r0) * cols.len()) as u64, Ordering::Relaxed);
        let xr = self.x.block(r0, r1, 0, self.x.cols());
        let xc = LandmarkCache::lookup(&self.landmarks, &self.x, cols);
        super::engine::rbf_cross_cpu_f32(&xr, &xc, self.gamma)
    }

    fn full_rows_f32(&self, r0: usize, r1: usize) -> MatrixF32 {
        self.entries
            .fetch_add(((r1 - r0) * self.n()) as u64, Ordering::Relaxed);
        if r0 == 0 && r1 == self.n() {
            return super::engine::rbf_gram_cpu_f32(&self.x, self.gamma);
        }
        let xr = self.x.block(r0, r1, 0, self.x.cols());
        super::engine::rbf_cross_cpu_f32(&xr, &self.x, self.gamma)
    }

    fn entries_observed(&self) -> u64 {
        self.entries.load(Ordering::Relaxed)
    }

    fn reset_entries(&self) {
        self.entries.store(0, Ordering::Relaxed);
    }
}

/// Polynomial kernel oracle: `K_ij = (gamma <x_i, x_j> + coef0)^degree`.
/// Exercises the poly_block artifact; any SPSD kernel works with the fast
/// model (degree must be a positive integer, coef0 >= 0, for SPSD-ness).
pub struct PolyOracle {
    x: Arc<Matrix>,
    pub gamma: f64,
    pub coef0: f64,
    pub degree: f64,
    engine: Arc<KernelEngine>,
    entries: AtomicU64,
    landmarks: Mutex<Option<LandmarkCache>>,
}

impl PolyOracle {
    pub fn new(x: Arc<Matrix>, gamma: f64, coef0: f64, degree: f64, engine: Arc<KernelEngine>) -> Self {
        PolyOracle {
            x,
            gamma,
            coef0,
            degree,
            engine,
            entries: AtomicU64::new(0),
            landmarks: Mutex::new(None),
        }
    }

    pub fn cpu(x: Arc<Matrix>, gamma: f64, coef0: f64, degree: f64) -> Self {
        Self::new(x, gamma, coef0, degree, Arc::new(KernelEngine::cpu()))
    }

    pub fn cross(&self, other: &Matrix) -> Matrix {
        self.engine
            .poly_cross(&self.x, other, self.gamma, self.coef0, self.degree)
    }
}

impl KernelOracle for PolyOracle {
    fn n(&self) -> usize {
        self.x.rows()
    }

    fn block(&self, rows: &[usize], cols: &[usize]) -> Matrix {
        self.entries
            .fetch_add((rows.len() * cols.len()) as u64, Ordering::Relaxed);
        let xr = self.x.select_rows(rows);
        let xc = self.x.select_rows(cols);
        self.engine
            .poly_cross(&xr, &xc, self.gamma, self.coef0, self.degree)
    }

    fn row_block(&self, r0: usize, r1: usize, cols: &[usize]) -> Matrix {
        self.entries
            .fetch_add(((r1 - r0) * cols.len()) as u64, Ordering::Relaxed);
        let xr = self.x.block(r0, r1, 0, self.x.cols());
        let xc = LandmarkCache::lookup(&self.landmarks, &self.x, cols);
        self.engine
            .poly_cross(&xr, &xc, self.gamma, self.coef0, self.degree)
    }

    fn full_rows(&self, r0: usize, r1: usize) -> Matrix {
        self.entries
            .fetch_add(((r1 - r0) * self.n()) as u64, Ordering::Relaxed);
        if r0 == 0 && r1 == self.n() {
            return self
                .engine
                .poly_cross(&self.x, &self.x, self.gamma, self.coef0, self.degree);
        }
        let xr = self.x.block(r0, r1, 0, self.x.cols());
        self.engine
            .poly_cross(&xr, &self.x, self.gamma, self.coef0, self.degree)
    }

    fn row_block_f32(&self, r0: usize, r1: usize, cols: &[usize]) -> MatrixF32 {
        self.entries
            .fetch_add(((r1 - r0) * cols.len()) as u64, Ordering::Relaxed);
        let xr = self.x.block(r0, r1, 0, self.x.cols());
        let xc = LandmarkCache::lookup(&self.landmarks, &self.x, cols);
        super::engine::poly_cross_cpu_f32(&xr, &xc, self.gamma, self.coef0, self.degree)
    }

    fn full_rows_f32(&self, r0: usize, r1: usize) -> MatrixF32 {
        self.entries
            .fetch_add(((r1 - r0) * self.n()) as u64, Ordering::Relaxed);
        if r0 == 0 && r1 == self.n() {
            return super::engine::poly_cross_cpu_f32(
                &self.x, &self.x, self.gamma, self.coef0, self.degree,
            );
        }
        let xr = self.x.block(r0, r1, 0, self.x.cols());
        super::engine::poly_cross_cpu_f32(&xr, &self.x, self.gamma, self.coef0, self.degree)
    }

    fn entries_observed(&self) -> u64 {
        self.entries.load(Ordering::Relaxed)
    }

    fn reset_entries(&self) {
        self.entries.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_kernel() -> Matrix {
        Matrix::from_fn(5, 5, |i, j| 1.0 / (1.0 + (i as f64 - j as f64).abs()))
    }

    #[test]
    fn dense_oracle_blocks_and_counts() {
        let o = DenseOracle::new(toy_kernel());
        let b = o.block(&[0, 2], &[1, 3, 4]);
        assert_eq!((b.rows(), b.cols()), (2, 3));
        assert_eq!(b[(1, 0)], 1.0 / 2.0); // K[2,1]
        assert_eq!(o.entries_observed(), 6);
        o.reset_entries();
        assert_eq!(o.entries_observed(), 0);
        let c = o.columns(&[0]);
        assert_eq!(c.rows(), 5);
        assert_eq!(o.entries_observed(), 5);
    }

    #[test]
    fn row_block_and_full_rows_match_block_access() {
        let mut rng = crate::util::Rng::new(7);
        let k = toy_kernel();
        let o = DenseOracle::new(k.clone());
        let cols = [0usize, 2, 4];
        let rows: Vec<usize> = (1..4).collect();
        assert_eq!(o.row_block(1, 4, &cols).max_abs_diff(&o.block(&rows, &cols)), 0.0);
        assert_eq!(o.full_rows(2, 5).max_abs_diff(&k.block(2, 5, 0, 5)), 0.0);
        o.reset_entries();
        let _ = o.row_block(0, 5, &cols);
        assert_eq!(o.entries_observed(), 15);

        let x = Arc::new(Matrix::randn(12, 3, &mut rng));
        let r = RbfOracle::cpu(Arc::clone(&x), 0.6);
        let all: Vec<usize> = (0..12).collect();
        let via_block = r.block(&(3..9).collect::<Vec<_>>(), &cols);
        assert_eq!(r.row_block(3, 9, &cols).max_abs_diff(&via_block), 0.0);
        let tile = r.full_rows(4, 8);
        let ref_tile = r.block(&(4..8).collect::<Vec<_>>(), &all);
        assert!(tile.max_abs_diff(&ref_tile) < 1e-14);

        let p = PolyOracle::cpu(Arc::clone(&x), 0.4, 1.0, 2.0);
        let via = p.block(&(0..5).collect::<Vec<_>>(), &cols);
        assert_eq!(p.row_block(0, 5, &cols).max_abs_diff(&via), 0.0);
    }

    #[test]
    fn rbf_oracle_matches_direct_formula() {
        let mut rng = crate::util::Rng::new(0);
        let x = Arc::new(Matrix::randn(12, 3, &mut rng));
        let o = RbfOracle::cpu(Arc::clone(&x), 0.7);
        let rows = [1usize, 5, 9];
        let cols = [0usize, 2, 3, 11];
        let b = o.block(&rows, &cols);
        for (i, &r) in rows.iter().enumerate() {
            for (j, &c) in cols.iter().enumerate() {
                let d2: f64 = (0..3).map(|t| (x[(r, t)] - x[(c, t)]).powi(2)).sum();
                let expect = (-0.7 * d2).exp();
                assert!((b[(i, j)] - expect).abs() < 1e-6, "({r},{c})");
            }
        }
        assert_eq!(o.entries_observed(), 12);
    }

    #[test]
    fn poly_oracle_matches_formula_and_is_spsd() {
        let mut rng = crate::util::Rng::new(3);
        let x = Arc::new(Matrix::randn(14, 3, &mut rng));
        let o = PolyOracle::cpu(Arc::clone(&x), 0.5, 1.0, 2.0);
        let k = o.full();
        for i in 0..14 {
            for j in 0..14 {
                let dot: f64 = (0..3).map(|t| x[(i, t)] * x[(j, t)]).sum();
                let expect = (0.5 * dot + 1.0).powi(2);
                assert!((k[(i, j)] - expect).abs() < 1e-9);
            }
        }
        // degree-2 polynomial kernel with coef0 > 0 is SPSD
        let e = crate::linalg::eigh(&k);
        assert!(e.values.iter().all(|&v| v > -1e-8));
    }

    #[test]
    fn fast_model_works_on_poly_kernel() {
        let mut rng = crate::util::Rng::new(4);
        let x = Arc::new(Matrix::randn(60, 4, &mut rng));
        let o = PolyOracle::cpu(x, 0.3, 1.0, 2.0);
        let k = o.full();
        o.reset_entries();
        let p = crate::spsd::uniform_p(60, 12, &mut rng);
        let a = crate::exec::fast(&o, &p, crate::spsd::FastConfig::uniform(36), &crate::exec::ExecPolicy::Materialized, &mut rng).result;
        // degree-2 poly kernel over R^4 has rank <= C(4+2,2) = 15; c=12
        // columns get close; error must at least be small and entries few
        let err = a.rel_fro_error(&k);
        assert!(err < 0.05, "err={err}");
        assert!(a.entries_observed < 60 * 60);
    }

    #[test]
    fn f32_tiles_match_f64_and_count_entries() {
        let mut rng = crate::util::Rng::new(5);
        let x = Arc::new(Matrix::randn(18, 3, &mut rng));
        let cols = [0usize, 4, 9, 17];
        for oracle in [
            Box::new(RbfOracle::cpu(Arc::clone(&x), 0.6)) as Box<dyn KernelOracle>,
            Box::new(PolyOracle::cpu(Arc::clone(&x), 0.4, 1.0, 2.0)),
        ] {
            oracle.reset_entries();
            let narrow = oracle.row_block_f32(2, 11, &cols);
            assert_eq!(oracle.entries_observed(), 9 * 4);
            let wide = oracle.row_block(2, 11, &cols);
            for i in 0..9 {
                for j in 0..4 {
                    assert!((wide[(i, j)] - narrow.row(i)[j] as f64).abs() < 1e-4);
                }
            }
            // typed dispatch agrees with the direct calls
            match oracle.row_block_elem(2, 11, &cols, Precision::F32) {
                Tile::F32(t) => assert_eq!(t.data(), narrow.data()),
                Tile::F64(_) => panic!("wrong width"),
            }
            let whole = oracle.full_rows_f32(0, 18);
            assert_eq!((whole.rows(), whole.cols()), (18, 18));
            // symmetric whole-gram path
            for i in 0..18 {
                for j in 0..18 {
                    assert_eq!(whole.row(i)[j].to_bits(), whole.row(j)[i].to_bits());
                }
            }
        }
        // DenseOracle exercises the default demote path
        let d = DenseOracle::new(toy_kernel());
        let narrow = d.row_block_f32(0, 5, &[1, 3]);
        let wide = d.row_block(0, 5, &[1, 3]);
        for i in 0..5 {
            for j in 0..2 {
                assert_eq!(narrow.row(i)[j], wide[(i, j)] as f32);
            }
        }
    }

    #[test]
    fn rbf_full_is_symmetric_unit_diagonal() {
        let mut rng = crate::util::Rng::new(1);
        let x = Arc::new(Matrix::randn(10, 4, &mut rng));
        let o = RbfOracle::cpu(x, 0.5);
        let k = o.full();
        assert!(k.max_abs_diff(&k.transpose()) < 1e-6);
        for i in 0..10 {
            assert!((k[(i, i)] - 1.0).abs() < 1e-9);
        }
    }
}
