//! Leverage-score invariants and the streamed estimator: the exact SVD
//! definition (`sketch::leverage_scores`), the Gram-based streamed
//! estimator (`sketch::approx_leverage_from_gram` + `stream::LeverageFold`),
//! and the sampler, pinned against each other on low-rank inputs with
//! fixed RNG.

use fastspsd::linalg::Matrix;
use fastspsd::sketch;
use fastspsd::stream::{run_pipeline, LeverageFold, LeverageSampler, MatrixSource};
use fastspsd::util::Rng;

fn low_rank(n: usize, d: usize, r: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::randn(n, r, &mut rng).matmul(&Matrix::randn(r, d, &mut rng))
}

#[test]
fn exact_scores_invariants() {
    // Non-negative, each ≤ 1, and summing to rank(C) — for full-rank and
    // rank-deficient panels alike.
    for (n, d, r, seed) in [(40usize, 6usize, 6usize, 1u64), (55, 8, 3, 2)] {
        let c = low_rank(n, d, r, seed);
        let l = sketch::leverage_scores(&c);
        assert_eq!(l.len(), n);
        let sum: f64 = l.iter().sum();
        assert!((sum - r as f64).abs() < 1e-7, "sum {sum} != rank {r}");
        for (i, &s) in l.iter().enumerate() {
            assert!((-1e-12..=1.0 + 1e-9).contains(&s), "score[{i}] = {s} out of [0, 1]");
        }
    }
}

#[test]
fn exact_scores_are_permutation_equivariant() {
    // scores(P·C) must equal P·scores(C): leverage is a per-row property.
    let c = low_rank(40, 6, 4, 3);
    let scores = sketch::leverage_scores(&c);
    let mut rng = Rng::new(4);
    let mut perm: Vec<usize> = (0..40).collect();
    rng.shuffle(&mut perm);
    let cp = c.select_rows(&perm);
    let sp = sketch::leverage_scores(&cp);
    for (j, &i) in perm.iter().enumerate() {
        assert!(
            (sp[j] - scores[i]).abs() < 1e-8,
            "permuted score {j} = {} vs original {i} = {}",
            sp[j],
            scores[i]
        );
    }
}

#[test]
fn gram_estimator_is_permutation_equivariant_too() {
    // The Gram is permutation-invariant, so the whitening factor — and
    // therefore every score — must be exactly equivariant.
    let c = low_rank(36, 5, 3, 5);
    let est = sketch::approx_leverage_from_gram(&c.gram_tn());
    let mut rng = Rng::new(6);
    let mut perm: Vec<usize> = (0..36).collect();
    rng.shuffle(&mut perm);
    let scores = est.scores(&c);
    let cp = c.select_rows(&perm);
    let sp = est.scores(&cp);
    for (j, &i) in perm.iter().enumerate() {
        assert_eq!(sp[j], scores[i], "row_score depends only on the row");
    }
}

#[test]
fn exact_vs_approx_agree_on_low_rank_with_fixed_rng() {
    // The streamed (Gram) estimator and the SVD definition must agree to
    // fp accuracy on a low-rank panel, and folding the Gram through the
    // tile pipeline must not change a bit of it.
    let c = low_rank(60, 8, 3, 7);
    let exact = sketch::leverage_scores(&c);
    let direct = sketch::approx_leverage_from_gram(&c.gram_tn());
    assert!((direct.rank - 3.0).abs() < 1e-6, "gram rank {}", direct.rank);

    let src = MatrixSource::new(&c);
    let mut fold = LeverageFold::exact(8);
    run_pipeline(&src, 13, 2, &mut [&mut fold]);
    let streamed = fold.into_estimate();
    assert_eq!(streamed.rank, direct.rank);

    for (i, (&e, (d, s))) in exact
        .iter()
        .zip(streamed.scores(&c).iter().zip(direct.scores(&c)))
        .enumerate()
    {
        assert!((d - e).abs() < 1e-8, "row {i}: streamed {d} vs svd {e}");
        assert!((s - e).abs() < 1e-8, "row {i}: direct {s} vs svd {e}");
    }
}

#[test]
fn sketched_surrogate_with_orthogonal_srht_is_exact() {
    // With m = n_pad rows the SRHT is a (scaled) orthogonal transform, so
    // the surrogate C^T Ω Ω^T C equals C^T C up to FWHT rounding and the
    // scores must match the exact ones.
    let n = 48; // pads to 64
    let c = low_rank(n, 7, 4, 8);
    let mut rng = Rng::new(9);
    let op = sketch::srht_sketch(n, 64, &mut rng);
    let src = MatrixSource::new(&c);
    let mut fold = LeverageFold::sketched(&op, 7);
    run_pipeline(&src, 11, 2, &mut [&mut fold]);
    let est = fold.into_estimate();
    let exact = sketch::leverage_scores(&c);
    for (i, (g, e)) in est.scores(&c).iter().zip(&exact).enumerate() {
        assert!((g - e).abs() < 1e-8, "row {i}: surrogate {g} vs exact {e}");
    }
}

#[test]
fn sketched_surrogate_statistical_sanity_at_small_m() {
    // m ≈ 4c rows: no exactness guarantee, but scores must stay
    // non-negative and their sum must land within a constant factor of the
    // rank (the surrogate rank normalizer the sampler divides by).
    let n = 64;
    let r = 3;
    let c = low_rank(n, 7, r, 10);
    let mut rng = Rng::new(11);
    let op = sketch::srht_sketch(n, 28, &mut rng);
    let src = MatrixSource::new(&c);
    let mut fold = LeverageFold::sketched(&op, 7);
    run_pipeline(&src, 9, 2, &mut [&mut fold]);
    let est = fold.into_estimate();
    let scores = est.scores(&c);
    assert!(scores.iter().all(|&s| s >= -1e-12), "negative surrogate score");
    let sum: f64 = scores.iter().sum();
    assert!(
        sum > r as f64 / 2.0 && sum < r as f64 * 2.0,
        "surrogate score mass {sum} far from rank {r}"
    );
}

#[test]
fn sampler_expected_size_tracks_target() {
    // With exact scores and no cap saturation the expected |S \ P| is the
    // target; check the empirical mean over seeds stays within ±50%.
    let c = low_rank(200, 10, 8, 12);
    let est = sketch::approx_leverage_from_gram(&c.gram_tn());
    let target = 16usize;
    let mut total = 0usize;
    let trials = 30u64;
    for t in 0..trials {
        let mut rng = Rng::new(100 + t);
        let mut s = LeverageSampler::new(&est, target, false, Vec::new(), 200, 10, &mut rng);
        let src = MatrixSource::new(&c);
        run_pipeline(&src, 32, 2, &mut [&mut s]);
        let (idx, _, _, sampled) = s.into_parts();
        assert_eq!(idx.len(), sampled, "no forced rows here");
        total += sampled;
    }
    let mean = total as f64 / trials as f64;
    assert!(
        mean > target as f64 * 0.5 && mean < target as f64 * 1.5,
        "mean |S| {mean} vs target {target}"
    );
}

#[test]
fn sampler_scaled_mode_uses_inverse_sqrt_p() {
    let c = low_rank(45, 6, 4, 13);
    let est = sketch::approx_leverage_from_gram(&c.gram_tn());
    let mut rng = Rng::new(14);
    let mut s = LeverageSampler::new(&est, 10, true, vec![7], 45, 6, &mut rng);
    let src = MatrixSource::new(&c);
    run_pipeline(&src, 45, 2, &mut [&mut s]);
    let (idx, scales, _, _) = s.into_parts();
    for (&i, &sc) in idx.iter().zip(&scales) {
        if i == 7 {
            assert_eq!(sc, 1.0, "forced rows are never rescaled");
        } else {
            let p = (10.0 * est.row_score(c.row(i)) / est.rank).min(1.0);
            assert!(
                (sc - 1.0 / p.sqrt()).abs() < 1e-12,
                "row {i}: scale {sc} vs 1/sqrt(p) {}",
                1.0 / p.sqrt()
            );
        }
    }
}
