//! Approximate kernel PCA (paper §6.3).
//!
//! Training: top-k eigenpairs of `C U C^T ≈ K` (via Lemma 10, O(n c^2)).
//! Feature extraction follows the paper: train features are columns of
//! `Λ^{1/2} V^T`; a test point's features are `Λ^{-1/2} V^T k(x)`.

use crate::linalg::{solve, Matrix};
use crate::spsd::SpsdApprox;

/// Top-k eigenpairs of (an approximation of) the kernel matrix.
#[derive(Debug, Clone)]
pub struct KpcaModel {
    /// Top-k eigenvalues, descending (clamped to >= 0).
    pub eigvals: Vec<f64>,
    /// n x k eigenvectors.
    pub v: Matrix,
}

/// KPCA from a low-rank approximation (the three models of the paper).
pub fn kpca_from_approx(approx: &SpsdApprox, k: usize) -> KpcaModel {
    let (mut vals, vecs) = solve::eig_k_of_cuc(&approx.c, &approx.u, k);
    for v in &mut vals {
        *v = v.max(0.0);
    }
    KpcaModel { eigvals: vals, v: vecs }
}

/// Exact KPCA baseline: top-k eigenpairs of the dense K via Lanczos
/// (O(n²k) — the "expensive exact" the paper times against, computed the
/// way a practitioner would).
pub fn exact_kpca(kmat: &Matrix, k: usize) -> KpcaModel {
    let (vals, vecs) = crate::linalg::lanczos_top_k(kmat, k, 0xE1A);
    KpcaModel { eigvals: vals.iter().map(|&v| v.max(0.0)).collect(), v: vecs }
}

impl KpcaModel {
    pub fn k(&self) -> usize {
        self.eigvals.len()
    }

    /// Train features, one row per training point: `(Λ^{1/2} V^T)^T = V Λ^{1/2}`.
    pub fn train_features(&self) -> Matrix {
        Matrix::from_fn(self.v.rows(), self.k(), |i, j| {
            self.v[(i, j)] * self.eigvals[j].max(0.0).sqrt()
        })
    }

    /// Test features from cross-kernel columns `kx` (n_train x n_test):
    /// row t of the result is `Λ^{-1/2} V^T k(x_t)`.
    pub fn test_features(&self, kx: &Matrix) -> Matrix {
        let vtk = self.v.tr_matmul(kx); // k x n_test
        let mut out = vtk.transpose(); // n_test x k
        let inv: Vec<f64> = self
            .eigvals
            .iter()
            .map(|&l| if l > 1e-12 { 1.0 / l.sqrt() } else { 0.0 })
            .collect();
        // scale row-major (one streaming pass instead of k column strides)
        for i in 0..out.rows() {
            for (v, &s) in out.row_mut(i).iter_mut().zip(&inv) {
                *v *= s;
            }
        }
        out
    }
}

/// Misalignment (paper eq. 10): `(1/k) ‖U_k - Ṽ Ṽ^T U_k‖_F^2 ∈ [0, 1]`,
/// where `U_k` are the exact top-k eigenvectors and `Ṽ` the approximate
/// ones.
pub fn misalignment(exact: &Matrix, approx: &Matrix) -> f64 {
    assert_eq!(exact.rows(), approx.rows());
    let k = exact.cols();
    let vtu = approx.tr_matmul(exact); // k̃ x k
    let proj = approx.matmul(&vtu); // Ṽ Ṽ^T U_k
    exact.sub(&proj).fro_norm_sq() / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::oracle::DenseOracle;
    use crate::exec::{self, ExecPolicy};
    use crate::spsd::{uniform_p, FastConfig};
    use crate::testkit::gen;
    use crate::util::Rng;

    #[test]
    fn exact_kpca_matches_eigh() {
        let mut rng = Rng::new(0);
        let k = gen::spsd(&mut rng, 20, 20);
        let m = exact_kpca(&k, 4);
        assert_eq!(m.k(), 4);
        // eigen equation
        for j in 0..4 {
            let v = m.v.col(j);
            let kv = k.matvec(&v);
            for i in 0..20 {
                assert!((kv[i] - m.eigvals[j] * v[i]).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn misalignment_zero_for_self_and_one_for_orthogonal() {
        let mut rng = Rng::new(1);
        let q = crate::linalg::qr::qr_thin(&Matrix::randn(20, 6, &mut rng)).q;
        let u = q.select_cols(&[0, 1, 2]);
        let v_same = q.select_cols(&[0, 1, 2]);
        assert!(misalignment(&u, &v_same) < 1e-12);
        let v_orth = q.select_cols(&[3, 4, 5]);
        assert!((misalignment(&u, &v_orth) - 1.0).abs() < 1e-10);
    }

    #[test]
    fn approx_kpca_matches_exact_on_low_rank() {
        let mut rng = Rng::new(2);
        let kmat = gen::spsd(&mut rng, 40, 5);
        let o = DenseOracle::new(kmat.clone());
        let p = uniform_p(40, 10, &mut rng);
        let a = exec::fast(&o, &p, FastConfig::uniform(20), &ExecPolicy::Materialized, &mut rng).result;
        let approx = kpca_from_approx(&a, 3);
        let exact = exact_kpca(&kmat, 3);
        assert!(misalignment(&exact.v, &approx.v) < 1e-8);
        for j in 0..3 {
            assert!((approx.eigvals[j] - exact.eigvals[j]).abs() < 1e-6 * exact.eigvals[0]);
        }
    }

    #[test]
    fn feature_shapes_and_test_consistency() {
        let mut rng = Rng::new(3);
        let kmat = gen::spsd(&mut rng, 15, 15);
        let m = exact_kpca(&kmat, 4);
        let f = m.train_features();
        assert_eq!((f.rows(), f.cols()), (15, 4));
        // Using K's own columns as "test" kernel vectors reproduces train
        // features: Λ^{-1/2} V^T K = Λ^{-1/2} Λ V^T = Λ^{1/2} V^T.
        let tf = m.test_features(&kmat);
        assert!(tf.max_abs_diff(&f) < 1e-7);
    }
}
