//! Approximate spectral clustering (paper §6.4 / Figs 11-12): NMI and
//! timing for Nyström / fast / prototype across sketch sizes.
//!
//! ```sh
//! cargo run --release --example spectral_clustering -- --dataset DNA
//! ```

use fastspsd::cli::Args;
use fastspsd::figures::{spectral_fig, Ctx};

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    argv.insert(0, "fig11".into());
    let args = Args::parse(argv);
    let ctx = Ctx::from_args(&args);
    spectral_fig::run(&ctx, &args);
}
